"""Grouped MoE dispatch under a real (8 fake device) mesh: the sharded
forward must match the single-device forward (the grouping changes capacity
semantics vs a global dispatch, but must be invariant to the mesh itself)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.layers import Ctx
    from repro.models.moe import moe_forward, moe_specs
    from repro.models.params import init_params
    from repro.sharding.rules import make_rules

    cfg = dataclasses.replace(get_smoke_config("moonshot-v1-16b-a3b"),
                              compute_dtype="float32")
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = make_rules(mesh, "train")
    ctx_sharded = Ctx(cfg=cfg, rules=rules)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
        y_sharded, aux_s = jax.jit(lambda p_, x_: moe_forward(ctx_sharded, p_, x_))(p, x)

    # reference: single-group (G=1) dispatch, no mesh
    ctx_plain = Ctx(cfg=cfg)
    y_plain, aux_p = jax.jit(lambda p_, x_: moe_forward(ctx_plain, p_, x_))(p, x)

    # G=4 grouping changes which tokens drop ONLY when capacity binds; the
    # smoke config uses capacity_factor=8 (no drops), so outputs must agree.
    err = float(jnp.max(jnp.abs(y_sharded - y_plain)))
    assert err < 1e-4, err
    print("OK", err)
    """
)


def test_grouped_moe_mesh_invariance(tmp_path):
    script = tmp_path / "moe_sharded.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(script)], env=env, cwd=os.getcwd(),
        capture_output=True, text=True, timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2500:]
    assert "OK" in res.stdout
