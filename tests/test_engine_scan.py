"""Fused serving engine tests: batched-gate parity, the hoisted / warm-started
/ sharded CCG, top-k bandwidth repair convergence, and the whole-run
``serve_scan`` driver vs the host-loop ``run_batch``."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import SystemConfig
from repro.core.features import feature_dim
from repro.core.gating import (
    GateConfig,
    gate_specs,
    gate_step,
    gate_step_batch,
    init_batch_state,
    init_state,
)
from repro.core.robust import RobustProblem, solve_ccg, solve_ccg_sharded
from repro.core.router import RouterEngine, enforce_bandwidth, init_router_state, route_scan, route_step
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.serving.scan import run_scan
from repro.serving.simulator import SimConfig, Simulator

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)
LAT = PROB.lat


# ---------------------------------------------------------------------------
# Fused batched gate vs the looped per-stream oracle
# ---------------------------------------------------------------------------
def _gate_setup(m=5, d=8, hid=16, window=4, seed=0):
    cfg = GateConfig(d_feature=d, d_hidden=hid, var_window=window)
    p = init_params(gate_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, p


def _looped_reference(cfg, p, dxs):
    """vmap-free oracle: gate_step per stream per step. dxs: (S, M, d)."""
    steps, m, _ = dxs.shape
    states = [init_state(cfg) for _ in range(m)]
    taus = np.zeros((steps, m))
    gs = np.zeros((steps, m))
    for t in range(steps):
        for i in range(m):
            states[i], (tau, g) = gate_step(cfg, p, states[i], dxs[t, i])
            taus[t, i] = float(tau)
            gs[t, i] = float(g)
    return taus, gs, states


def test_gate_step_batch_matches_looped_gate_step():
    """Incremental-variance fused step == per-stream loop over a multi-step
    sequence that wraps the ring buffer (steps > var_window)."""
    cfg, p = _gate_setup(window=4)
    steps = 11  # > var_window: exercises eviction/wraparound
    dxs = jax.random.normal(jax.random.PRNGKey(2), (steps, 5, cfg.d_feature))
    taus_ref, gs_ref, states_ref = _looped_reference(cfg, p, dxs)

    st = init_batch_state(cfg, 5)
    taus = np.zeros((steps, 5))
    gs = np.zeros((steps, 5))
    for t in range(steps):
        st, (tau, g) = gate_step_batch(cfg, p, st, dxs[t])
        taus[t] = np.asarray(tau)
        gs[t] = np.asarray(g)
    np.testing.assert_allclose(taus, taus_ref, atol=1e-5)
    np.testing.assert_allclose(gs, gs_ref, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(st.h), np.stack([s.h for s in states_ref]), atol=1e-5)
    assert np.all(np.asarray(st.var_idx) == steps)
    # the incremental running sums agree with a fresh scan of the buffer
    np.testing.assert_allclose(
        np.asarray(st.var_sum), np.asarray(st.var_buf.sum(axis=1)), atol=1e-4)


def test_gate_step_batch_pallas_interpret_parity():
    """The Pallas cell (interpret mode on CPU) matches the ref dispatch."""
    cfg, p = _gate_setup(m=4)
    dxs = jax.random.normal(jax.random.PRNGKey(7), (6, 4, cfg.d_feature))
    st_ref = init_batch_state(cfg, 4)
    st_pal = init_batch_state(cfg, 4)
    for t in range(6):
        st_ref, (tau_r, _) = gate_step_batch(cfg, p, st_ref, dxs[t], force="ref")
        st_pal, (tau_p, _) = gate_step_batch(cfg, p, st_pal, dxs[t], force="pallas")
        np.testing.assert_allclose(np.asarray(tau_p), np.asarray(tau_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_pal.h), np.asarray(st_ref.h), atol=1e-5)


def test_gate_cell_pads_odd_batches():
    """Pallas dispatch pads B up to the block size, so any batch works."""
    from repro.kernels.temporal_gate.ops import gate_cell

    cfg, p = _gate_setup()
    b = 5
    dx = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.d_feature))
    h = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.d_hidden)) * 0.1
    vol = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (b,)))
    got = gate_cell(dx, h, vol, p, block_b=4, force="pallas")
    want = gate_cell(dx, h, vol, p, force="ref")
    for g, w in zip(got, want):
        assert g.shape == w.shape
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)


# ---------------------------------------------------------------------------
# Hoisted / warm-started / sharded CCG
# ---------------------------------------------------------------------------
def test_solve_ccg_sharded_matches_dense():
    """shard_map on the host mesh returns identical decisions + bounds.

    The host mesh has a size-1 data axis; the real multi-shard + padding
    path is covered by ``test_solve_ccg_sharded_multidevice`` below.
    """
    mesh = make_host_mesh()
    rng = np.random.default_rng(42)
    for m in (8, 13):
        z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
        aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
        sol = solve_ccg(PROB, z, aq)
        sol_s = solve_ccg_sharded(PROB, z, aq, mesh)
        assert set(sol) == set(sol_s)
        for k in sol:
            np.testing.assert_array_equal(np.asarray(sol[k]), np.asarray(sol_s[k]))


def test_solve_ccg_sharded_multidevice():
    """4 fake host devices, M=13 (pad to 16): decisions identical to dense.

    Runs in a subprocess (device count locks at first jax init — same idiom
    as tests/test_pipeline.py)."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.core.robust import RobustProblem, solve_ccg, solve_ccg_sharded

        prob = RobustProblem.build(SystemConfig())
        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(42)
        for m in (13, 16):  # 13: padding path; 16: exact split
            z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
            aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
            sol = solve_ccg(prob, z, aq)
            sol_s = solve_ccg_sharded(prob, z, aq, mesh)
            for k in sol:
                np.testing.assert_array_equal(np.asarray(sol[k]), np.asarray(sol_s[k]))
        print("OK")
        """
    )
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_solve_ccg_warm_start_preserves_decisions_fewer_iters():
    """Seeding the scenario set with a feasible warm start must not change
    the converged decisions and can only reduce CCG iterations."""
    rng = np.random.default_rng(1234)
    z = jnp.asarray(rng.uniform(0, 1, 16), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, 16), jnp.float32)
    cold = solve_ccg(PROB, z, aq)
    warm_y = LAT.flatten_index(cold["route"], cold["r"], cold["p"]).astype(jnp.int32)
    warm = solve_ccg(PROB, z, aq, warm_y=warm_y)
    for k in ("route", "r", "p", "v"):
        np.testing.assert_array_equal(np.asarray(cold[k]), np.asarray(warm[k]))
    np.testing.assert_allclose(np.asarray(cold["o_up"]), np.asarray(warm["o_up"]),
                               rtol=1e-6)
    assert np.all(np.asarray(warm["iters"]) <= np.asarray(cold["iters"]))
    assert np.asarray(warm["iters"]).sum() < np.asarray(cold["iters"]).sum()


def test_solve_ccg_ignores_infeasible_warm_start():
    """A warm start pointing at an infeasible first-stage option must not
    corrupt the bounds (falls back to the cold init for that task)."""
    z = jnp.asarray([0.5, 0.5], jnp.float32)
    aq = jnp.asarray([0.6, 0.6], jnp.float32)
    cold = solve_ccg(PROB, z, aq)
    # y=0 is the cheapest edge config at min fps — generally infeasible here
    warm = solve_ccg(PROB, z, aq, warm_y=jnp.zeros((2,), jnp.int32))
    np.testing.assert_allclose(np.asarray(cold["o_up"]), np.asarray(warm["o_up"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Top-k bandwidth repair
# ---------------------------------------------------------------------------
def test_enforce_bandwidth_topk_converges_in_few_rounds():
    """Multi-task demotion clears the budget in ~#fidelity-levels rounds even
    for a large batch (the scalar one-per-round repair needed O(M) rounds)."""
    m = 48
    rng = np.random.default_rng(7)
    z = jnp.asarray(rng.uniform(0.1, 0.6, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.6, m), jnp.float32)
    sol = {
        "route": jnp.zeros((m,), jnp.int32),
        "r": jnp.full((m,), SYS.n_res - 1, jnp.int32),
        "p": jnp.full((m,), SYS.n_fps - 1, jnp.int32),
        "v": jnp.full((m,), SYS.num_versions - 1, jnp.int32),
    }
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    budget = 0.6 * start_bw
    fixed, _ = enforce_bandwidth(SYS, sol, z, aq, total_budget=budget, rounds=8)
    final_bw = float(np.asarray(LAT.solution_bandwidth(fixed)).sum())
    assert final_bw <= budget + 1e-6, (final_bw, budget)


# ---------------------------------------------------------------------------
# Scan drivers
# ---------------------------------------------------------------------------
def test_route_scan_matches_sequential_route_step():
    """One lax.scan over S segments == S sequential route_step calls."""
    m, s = 6, 5
    rng = np.random.default_rng(3)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, m), jnp.float32)
    dx_seq = jnp.asarray(rng.normal(size=(s, m, feature_dim())), jnp.float32)

    state = init_router_state(gcfg, m)
    seq_sols = []
    for t in range(s):
        state, sol = route_step(PROB, gcfg, gparams, state, dx_seq[t], z, aq)
        seq_sols.append(sol)

    state2 = init_router_state(gcfg, m)
    state2, sols = route_scan(PROB, gcfg, gparams, state2, dx_seq, z, aq)
    for k in ("route", "r", "p", "v"):
        want = np.stack([np.asarray(s_[k]) for s_ in seq_sols])
        np.testing.assert_array_equal(np.asarray(sols[k]), want)
    np.testing.assert_allclose(
        np.asarray(sols["tau"]),
        np.stack([np.asarray(s_["tau"]) for s_ in seq_sols]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(state2.prev_route),
                                  np.asarray(state.prev_route))


def test_serve_scan_matches_host_loop_metrics():
    """The whole-run compiled driver reproduces a host loop driving the
    RouterEngine round by round on a fixed seed (same rounds, same noise
    draw) — the R2E-VID path's host-loop oracle."""
    scfg = SimConfig(n_rounds=5, n_tasks=16, seed=7, bw_fluctuation=0.15)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))

    sim_a = Simulator(SYS, scfg)
    out_a = run_scan(sim_a, gcfg, gparams, feature_seed=0)

    sim_b = Simulator(SYS, scfg)
    frng = np.random.default_rng(0)
    dx_seq = jnp.asarray(
        frng.normal(size=(scfg.n_rounds, scfg.n_tasks, feature_dim())), jnp.float32)
    engine = RouterEngine(PROB, gcfg, gparams, n_streams=scfg.n_tasks)
    rnds, cfgs = [], []
    for i in range(scfg.n_rounds):
        rnd = sim_b.sample_round()
        sol = engine.step(dx_seq[i], jnp.asarray(rnd["z"]), jnp.asarray(rnd["aq"]))
        rnds.append(rnd)
        cfgs.append({k: np.asarray(sol[k]) for k in ("route", "r", "p", "v")})
    met = sim_b.realize_batch(rnds, cfgs)
    out_b = {k: float(met[k].mean(axis=1).mean())
             for k in ("delay", "energy", "cost", "accuracy", "success")}
    out_b["cloud_frac"] = float(met["route"].mean(axis=1).mean())
    assert set(out_a) == set(out_b)
    for k in out_a:
        np.testing.assert_allclose(out_a[k], out_b[k], atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# Unrolled masked CCG vs the while_loop oracle
# ---------------------------------------------------------------------------
def _assert_ccg_identical(sol_a, sol_b, msg=""):
    assert set(sol_a) == set(sol_b)
    for k in sol_a:
        np.testing.assert_array_equal(
            np.asarray(sol_a[k]), np.asarray(sol_b[k]), err_msg=f"{msg}:{k}")


def test_unrolled_ccg_matches_while_loop():
    """Fixed-unroll masked iteration == per-task while_loop: decisions,
    bounds, and iteration counts bit-identical on a mixed random batch,
    cold and warm-started."""
    from repro.core.robust import solve_ccg_while

    rng = np.random.default_rng(99)
    z = jnp.asarray(rng.uniform(0, 1, 37), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, 37), jnp.float32)
    cold_u = solve_ccg(PROB, z, aq)
    cold_w = solve_ccg_while(PROB, z, aq)
    _assert_ccg_identical(cold_u, cold_w, "cold")

    warm_y = LAT.flatten_index(cold_w["route"], cold_w["r"], cold_w["p"])
    warm_u = solve_ccg(PROB, z, aq, warm_y=warm_y.astype(jnp.int32))
    warm_w = solve_ccg_while(PROB, z, aq, warm_y=warm_y.astype(jnp.int32))
    _assert_ccg_identical(warm_u, warm_w, "warm")


def test_unrolled_ccg_matches_while_loop_adversarial():
    """Adversarial lanes: a warm start pointing at an infeasible option
    (warm miss), a task no configuration can satisfy (margin fallback), and
    easy tasks mixed in — all bit-identical to the while_loop solver."""
    from repro.core.robust import solve_ccg_while

    z = jnp.asarray([0.5, 0.9, 0.05, 0.7], jnp.float32)
    aq = jnp.asarray([0.6, 0.99, 0.5, 0.65], jnp.float32)   # task 1 infeasible
    # task 0: warm miss (y=0 is the cheapest, generally infeasible config);
    # task 1: warm miss on an all-infeasible task; others: no warm start
    warm_y = jnp.asarray([0, 0, -1, -1], jnp.int32)
    sol_u = solve_ccg(PROB, z, aq, warm_y=warm_y)
    sol_w = solve_ccg_while(PROB, z, aq, warm_y=warm_y)
    _assert_ccg_identical(sol_u, sol_w, "adversarial")
    assert np.asarray(sol_u["infeasible"]).tolist() == [False, True, False, False]


def test_unrolled_ccg_matches_while_loop_p1_degenerate():
    """Γ=0 leaves a single (all-zero) pole: the unroll collapses to
    min(max_iters, 2) steps and must still match the while_loop solver."""
    from repro.core.robust import solve_ccg_while

    prob1 = RobustProblem.build(SystemConfig(gamma=0))
    assert prob1.poles.shape[0] == 1
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.uniform(0, 1, 11), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, 11), jnp.float32)
    _assert_ccg_identical(
        solve_ccg(prob1, z, aq), solve_ccg_while(prob1, z, aq), "p1")
    assert int(np.asarray(solve_ccg(prob1, z, aq)["iters"]).max()) <= 2


def test_unrolled_ccg_slab_master_paths_identical():
    """The slab-master op (ref and Pallas-interpret) and the incremental-η
    jnp master produce identical solutions — the three master
    implementations are interchangeable."""
    rng = np.random.default_rng(17)
    z = jnp.asarray(rng.uniform(0, 1, 19), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, 19), jnp.float32)
    auto = solve_ccg(PROB, z, aq)
    _assert_ccg_identical(auto, solve_ccg(PROB, z, aq, force="ref"), "ref")
    _assert_ccg_identical(auto, solve_ccg(PROB, z, aq, force="pallas"), "pallas")


# ---------------------------------------------------------------------------
# End-to-end sharded serve_scan
# ---------------------------------------------------------------------------
def test_serve_scan_accepts_host_mesh():
    """On the 1-device host mesh the sharded path must agree with dense."""
    from repro.core.robust import RobustProblem as RP
    from repro.serving.scan import serve_scan

    m, r = 6, 3
    rng = np.random.default_rng(21)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    dx = jnp.asarray(rng.normal(size=(r, m, feature_dim())), jnp.float32)
    z = jnp.asarray(rng.uniform(0, 1, (r, m)), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, (r, m)), jnp.float32)
    bwm = jnp.asarray(rng.uniform(0.8, 1.0, (r, 2)), jnp.float32)
    u = jnp.asarray(rng.uniform(0, 0.3, (r, 5)), jnp.float32)

    st_a, met_a = serve_scan(PROB, gcfg, gparams, init_router_state(gcfg, m),
                             dx, z, aq, bwm, u)
    st_b, met_b = serve_scan(PROB, gcfg, gparams, init_router_state(gcfg, m),
                             dx, z, aq, bwm, u, mesh=mesh)
    assert set(met_a) == set(met_b)
    for k in met_a:
        np.testing.assert_allclose(np.asarray(met_a[k]), np.asarray(met_b[k]),
                                   atol=1e-5, err_msg=k)
    np.testing.assert_array_equal(np.asarray(st_a.prev_route),
                                  np.asarray(st_b.prev_route))


def test_serve_scan_sharded_multidevice():
    """4 fake host devices: the whole-run sharded scan (gate + Stage-1 +
    unrolled CCG sharded over streams, C6 + realization on the gathered real
    batch) reproduces the dense metrics and final state for M=13 (padding:
    13 streams over 4 devices) and M=16 (exact split).  Subprocess because
    the device count locks at first jax init."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.core.features import feature_dim
        from repro.core.gating import GateConfig, gate_specs
        from repro.core.robust import RobustProblem
        from repro.core.router import init_router_state
        from repro.models.params import init_params
        from repro.serving.scan import serve_scan

        prob = RobustProblem.build(SystemConfig())
        gcfg = GateConfig(d_feature=feature_dim())
        gp = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("data",))
        for m in (13, 16):  # 13: padding path; 16: exact split
            rng = np.random.default_rng(m)
            r = 4
            dx = jnp.asarray(rng.normal(size=(r, m, feature_dim())), jnp.float32)
            z = jnp.asarray(rng.uniform(0, 1, (r, m)), jnp.float32)
            aq = jnp.asarray(rng.uniform(0.5, 0.7, (r, m)), jnp.float32)
            bwm = jnp.asarray(rng.uniform(0.8, 1.0, (r, 2)), jnp.float32)
            u = jnp.asarray(rng.uniform(0, 0.3, (r, 5)), jnp.float32)
            st_a, met_a = serve_scan(prob, gcfg, gp, init_router_state(gcfg, m),
                                     dx, z, aq, bwm, u)
            st_b, met_b = serve_scan(prob, gcfg, gp, init_router_state(gcfg, m),
                                     dx, z, aq, bwm, u, mesh=mesh)
            assert set(met_a) == set(met_b)
            for k in met_a:
                np.testing.assert_allclose(
                    np.asarray(met_a[k]), np.asarray(met_b[k]), atol=1e-5,
                    err_msg=f"M={m}:{k}")
            np.testing.assert_array_equal(np.asarray(st_a.prev_route),
                                          np.asarray(st_b.prev_route))
            np.testing.assert_allclose(np.asarray(st_a.gate.h),
                                       np.asarray(st_b.gate.h), atol=1e-5)
        print("OK")
        """
    )
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
