"""End-to-end behaviour tests for the R2E-VID system: video stream ->
motion features -> temporal gate -> two-stage robust routing -> pools."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GateConfig, RobustProblem, RouterConfig, SystemConfig,
                        feature_dim, gate_specs, route, segment_features,
                        stage1_configure)
from repro.data.video import VideoConfig, generate_stream, make_task_batch
from repro.models.params import init_params

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)
GCFG = GateConfig(d_feature=feature_dim())


def _pipeline_inputs(n_streams=6, n_segments=8, seed=0):
    vcfg = VideoConfig()
    streams = [generate_stream(vcfg, n_segments, rng=np.random.default_rng(seed + i))
               for i in range(n_streams)]
    dx = jnp.stack([
        segment_features(jnp.asarray(f), vcfg.frames_per_segment) for f, _ in streams
    ])
    z = jnp.asarray([m.mean() for _, m in streams])
    aq = jnp.asarray(make_task_batch(n_streams, "stable", seed=seed))
    return dx, z, aq


def test_route_pipeline_end_to_end():
    dx, z, aq = _pipeline_inputs()
    gp = init_params(gate_specs(GCFG), jax.random.PRNGKey(0))
    sol = route(PROB, GCFG, gp, dx, z, aq)
    m = dx.shape[0]
    for key in ("route", "r", "p", "v", "tau"):
        assert sol[key].shape == (m,)
    assert jnp.all((sol["tau"] >= 0) & (sol["tau"] <= 1))
    assert jnp.all((sol["route"] == 0) | (sol["route"] == 1))
    assert jnp.all((sol["r"] >= 0) & (sol["r"] < SYS.n_res))
    assert jnp.all((sol["v"] >= 0) & (sol["v"] < SYS.num_versions))


def test_temporal_consistency_blocks_flapping():
    """With a previous route and a tiny gate move, the route must hold."""
    dx, z, aq = _pipeline_inputs()
    gp = init_params(gate_specs(GCFG), jax.random.PRNGKey(0))
    sol1 = route(PROB, GCFG, gp, dx, z, aq)
    prev_route = 1 - sol1["route"]  # force disagreement with next decision
    # same gate state -> |Δτ| ~ 0 -> flips forbidden -> must keep prev_route
    sol2 = route(PROB, GCFG, gp, dx, z, aq,
                 prev_route=prev_route, prev_tau=sol1["tau"],
                 rcfg=RouterConfig(delta1=4.0))
    np.testing.assert_array_equal(np.asarray(sol2["route"]), np.asarray(prev_route))


def test_stage1_escalates_infeasible_to_cloud():
    taus = jnp.asarray([0.1, 0.1])
    z = jnp.asarray([1.0, 0.05])
    # task 0: very hard content + high requirement -> edge v1 infeasible
    aq = jnp.asarray([0.68, 0.55])
    prev = -jnp.ones((2,), jnp.int32)
    route_idx, r_idx = stage1_configure(SYS, taus, z, aq, prev, jnp.zeros((2,)))
    assert int(route_idx[0]) == 1  # escalated (Alg. 1 line 8)
    assert int(route_idx[1]) == 0  # easy task stays on edge


def test_stage1_picks_smallest_feasible_resolution():
    taus = jnp.asarray([0.1])
    z = jnp.asarray([0.1])
    aq = jnp.asarray([0.52])
    prev = -jnp.ones((1,), jnp.int32)
    _, r_idx = stage1_configure(SYS, taus, z, aq, prev, jnp.zeros((1,)))
    from repro.core.cost_model import accuracy_table
    f = np.asarray(accuracy_table(SYS, z))[0, :, -1, 0, 0]  # edge v1 at max fps
    first_ok = int(np.argmax(f >= 0.52))
    assert int(r_idx[0]) == first_ok


def test_router_is_deterministic():
    """Two identical calls give identical routing (pure function of inputs)."""
    dx, z, aq = _pipeline_inputs(seed=3)
    gp = init_params(gate_specs(GCFG), jax.random.PRNGKey(0))
    s1 = route(PROB, GCFG, gp, dx, z, aq)
    s2 = route(PROB, GCFG, gp, dx, z, aq)
    np.testing.assert_array_equal(np.asarray(s1["route"]), np.asarray(s2["route"]))
    np.testing.assert_array_equal(np.asarray(s1["v"]), np.asarray(s2["v"]))


def test_pools_serve_routed_segments():
    from repro.configs import get_smoke_config
    from repro.serving.pools import make_tier_pools

    pools = make_tier_pools(get_smoke_config("qwen1.5-0.5b"),
                            get_smoke_config("qwen3-8b"))
    toks = jnp.ones((2, 16), jnp.int32)
    out = pools[0].serve_segment(toks, decode_tokens=4)
    assert out.shape == (2, 4)
    assert pools[0].stats.tokens == 2 * 20
