import jax
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke tests
# and benches must see the real (1-device) host.  The multi-pod dry-run sets
# it itself as the very first lines of repro.launch.dryrun.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
