"""Continuous-batching dispatch executor: parity, scheduling invariants,
and the measured-feedback loop into the router.

The serial ``ModelPool.serve_segment`` path is the parity oracle: the
executor's bucketed prefills + token-level slab decode must reproduce its
decoded ids request-for-request, regardless of co-batching, arrival order,
or tier interleave.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import SystemConfig
from repro.serving.dispatch import (
    DispatchExecutor,
    PoolExecutor,
    Request,
    serve_serial_oracle,
)
from repro.serving.policy import Observation, make_policy
from repro.serving.pools import ModelPool, make_tier_pools
from repro.serving.session import AdmissionConfig, ServeSession

SYS = SystemConfig()


class _TickClock:
    """Deterministic clock: each read advances one tick.  Waits and services
    become schedule-step counts, so feedback assertions are exact."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


@pytest.fixture(scope="module")
def pools():
    return make_tier_pools(get_smoke_config("qwen1.5-0.5b"),
                           get_smoke_config("qwen3-8b"))


def _mixed_requests(pools, m=12, seed=0, decode_tokens=6):
    """Mixed-tier, mixed-length request set (prompt lengths 16/32/48 — the
    discrete fidelity sizes the session's dispatch produces)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(m):
        tier = int(rng.integers(0, 2))
        n = 16 * int(rng.integers(1, 4))
        vocab = pools[tier].cfg.vocab_size
        toks = ((i * 131 + np.arange(n)) % vocab).astype(np.int32)
        reqs.append(Request(stream=i, tier=tier, tokens=toks,
                            decode_tokens=decode_tokens))
    return reqs


# ---------------------------------------------------------------------------
# Parity with the serial oracle
# ---------------------------------------------------------------------------
def test_executor_matches_serial_oracle(pools):
    reqs = _mixed_requests(pools, m=12)
    want = serve_serial_oracle(
        pools, [dataclasses.replace(r) for r in reqs])
    ex = DispatchExecutor(pools, n_slots=4, max_prefill_batch=2)
    stats = ex.serve(reqs)
    got = {c.stream: c.ids
           for t in ex.execs for c in ex.execs[t].completions}
    assert set(got) == set(want)
    for s in want:
        np.testing.assert_array_equal(got[s], want[s],
                                      err_msg=f"stream {s} ids diverge")
    # the returned stats cover exactly this request set
    assert sum(st["requests"] for st in stats.values()) == len(reqs)
    toks = sum(st["tokens"] for st in stats.values())
    assert toks == sum(len(r.tokens) + r.decode_tokens for r in reqs)


def test_join_leave_does_not_perturb_decodes(pools):
    """A segment's decoded ids are independent of which other segments share
    its decode batch: serve one request alone, then co-batched with segments
    that join mid-flight and leave early — identical ids."""
    vocab = pools[0].cfg.vocab_size
    mk = lambda s, n, d: Request(
        stream=s, tier=0,
        tokens=((s * 131 + np.arange(n)) % vocab).astype(np.int32),
        decode_tokens=d)

    alone = DispatchExecutor(pools, n_slots=4)
    alone.serve([mk(0, 32, 10)])
    want = alone.execs[0].completions[0].ids

    ex = DispatchExecutor(pools, n_slots=4, max_prefill_batch=2)
    # short-lived neighbor admitted with stream 0, leaves after 2 decodes
    ex.submit([mk(0, 32, 10), mk(1, 32, 2)])
    for _ in range(4):
        ex.step()
    # late joiner at a different prompt length, different cache depth
    ex.submit([mk(2, 16, 6)])
    ex.drain()
    got = {c.stream: c.ids for c in ex.execs[0].completions}
    np.testing.assert_array_equal(got[0], want)
    # neighbors also match their own solo references
    for s, n, d in ((1, 32, 2), (2, 16, 6)):
        solo = DispatchExecutor(pools, n_slots=4)
        solo.serve([mk(s, n, d)])
        np.testing.assert_array_equal(got[s],
                                      solo.execs[0].completions[0].ids)


# ---------------------------------------------------------------------------
# Scheduling invariants
# ---------------------------------------------------------------------------
def test_queue_drains_and_no_starvation(pools):
    """Every submitted request completes, and the oldest pending request is
    always part of the next admitted prefill bucket (FIFO head defines the
    bucket) — no length class waits unboundedly."""
    reqs = _mixed_requests(pools, m=16, seed=1, decode_tokens=4)
    ex = DispatchExecutor(pools, n_slots=2, max_prefill_batch=2)
    ex.serve(reqs)
    assert ex.idle
    done = {c.stream for t in ex.execs for c in ex.execs[t].completions}
    assert done == {r.stream for r in reqs}
    for t, pex in ex.execs.items():
        for admitted, oldest in pex.admission_log:
            assert oldest in admitted, (
                f"tier {t}: oldest pending stream {oldest} skipped by "
                f"bucket {admitted}")


def test_submit_validates_prompt_length(pools):
    ex = PoolExecutor(pools[0], n_slots=2, max_prefill_len=48)
    with pytest.raises(ValueError, match="prompt length"):
        ex.submit(Request(stream=0, tier=0,
                          tokens=np.zeros((49,), np.int32)))
    with pytest.raises(ValueError, match="prompt length"):
        ex.submit(Request(stream=0, tier=0,
                          tokens=np.zeros((0,), np.int32)))


def test_serve_empty_request_set(pools):
    ex = DispatchExecutor(pools)
    assert ex.serve([]) == {}
    assert ex.idle


def test_serial_path_b0_regression(pools):
    out = pools[0].serve_segment(jnp.zeros((0, 16), jnp.int32),
                                 decode_tokens=4)
    assert out.shape == (0, 4)


# ---------------------------------------------------------------------------
# Stats / measurement
# ---------------------------------------------------------------------------
def test_pool_stats_latency_percentiles(pools):
    pool = ModelPool(get_smoke_config("qwen1.5-0.5b"))
    before = pool.stats.requests
    pool.serve_segment(jnp.ones((3, 16), jnp.int32), decode_tokens=4)
    st = pool.stats
    assert st.requests == before + 3
    assert len(st.latencies) == 3
    assert st.tokens_per_s > 0
    assert 0 < st.p50_s() <= st.p99_s()
    s = st.summary()
    assert {"requests", "tokens", "tokens_per_s", "p50_s", "p99_s"} <= set(s)


def test_dispatch_returns_latency_stats_not_bare_counts(pools):
    ex = DispatchExecutor(pools, n_slots=4, clock=_TickClock())
    stats = ex.serve(_mixed_requests(pools, m=8, seed=2, decode_tokens=4))
    for t, st in stats.items():
        assert st["requests"] > 0
        assert st["tokens_per_s"] > 0
        assert 0 < st["p50_s"] <= st["p99_s"]
        assert st["mean_service_s"] > 0


def test_feedback_loaded_tier_reports_lower_mult(pools):
    """Queueing on one tier shrinks its measured multiplier; an idle tier
    reports 1.0 (no evidence, no adjustment)."""
    clock = _TickClock()
    ex = DispatchExecutor(pools, n_slots=2, max_prefill_batch=2, clock=clock)
    vocab = pools[1].cfg.vocab_size
    reqs = [Request(stream=i, tier=1,
                    tokens=((i * 131 + np.arange(16)) % vocab).astype(np.int32),
                    decode_tokens=4)
            for i in range(12)]
    ex.serve(reqs)
    fb = ex.feedback()
    assert fb["bw_mult"][0] == 1.0           # edge never served: passthrough
    assert fb["bw_mult"][1] < 1.0            # cloud queued: degraded
    assert fb["per_tier"][1]["wait_ewma_s"] > 0
    # reset forgets measurements: feedback returns to passthrough
    ex.reset_measurements()
    fb2 = ex.feedback()
    assert fb2["bw_mult"][1] == 1.0


# ---------------------------------------------------------------------------
# Session integration
# ---------------------------------------------------------------------------
def _session(pools, m, admission=None):
    return ServeSession(make_policy("r2evid", SYS), m, pools=pools,
                        admission=admission)


def test_session_dispatch_sizes_tokens_per_segment(pools):
    """Each routed segment's prompt is sized by ITS OWN fidelity — 16·(1+r_i)
    — not the tier mean the deprecated serial path used."""
    sess = _session(pools, 6)
    sol = {"route": jnp.asarray([0, 0, 1, 1, 1, 0], jnp.int32),
           "r": jnp.asarray([0, 2, 1, 4, 0, 1], jnp.int32),
           "p": jnp.zeros((6,), jnp.int32), "v": jnp.zeros((6,), jnp.int32)}
    sess.dispatch(sol, decode_tokens=2)
    got = {c.stream: c.n_prefill
           for t in sess.executor.execs
           for c in sess.executor.execs[t].completions}
    r = np.asarray(sol["r"])
    assert got == {i: 16 * (1 + int(r[i])) for i in range(6)}


def test_session_dispatch_skips_churned_lanes(pools):
    """Dead slot-pool lanes (route == -1) are never enqueued."""
    sess = _session(pools, 5)
    sol = {"route": jnp.asarray([0, -1, 1, -1, 0], jnp.int32),
           "r": jnp.zeros((5,), jnp.int32),
           "p": jnp.zeros((5,), jnp.int32), "v": jnp.zeros((5,), jnp.int32)}
    sess.dispatch(sol, decode_tokens=2)
    done = {c.stream for t in sess.executor.execs
            for c in sess.executor.execs[t].completions}
    assert done == {0, 2, 4}


def test_session_feedback_changes_routing_decisions(pools):
    """The acceptance loop: a loaded tier's measured feedback, folded into
    the next round's observation via ``apply_feedback``, changes what the
    router decides.  The feedback-scaled ``bw_scale`` shrinks the admission
    budget below the scarcity threshold, so streams admitted under load are
    pinned to minimum fidelity — decisions a feedback-blind session does
    not make."""
    m, rounds = 8, 3
    clock = _TickClock()
    sess = _session(pools, m, admission=AdmissionConfig(init_alive=4))
    sess._executor = DispatchExecutor(
        pools, n_slots=2, max_prefill_batch=2, clock=clock)

    # round 0: serve a routed solution on live pools — cloud heavily loaded,
    # edge lightly (both queue behind the 2-slot slab, cloud much deeper)
    route = np.array([1] * 6 + [0] * 2, np.int32)
    sol = {"route": jnp.asarray(np.tile(route, 3)),
           "r": jnp.ones((3 * m,), jnp.int32),
           "p": jnp.zeros((3 * m,), jnp.int32),
           "v": jnp.zeros((3 * m,), jnp.int32)}
    sess.dispatch(sol, decode_tokens=4)

    fb = sess.feedback()
    assert fb["bw_mult"][1] < 1.0, "loaded cloud tier must report degraded"

    rng = np.random.default_rng(0)
    stream = Observation(
        z=jnp.asarray(rng.uniform(0.4, 0.8, (rounds, m)), jnp.float32),
        aq=jnp.asarray(rng.uniform(0.6, 0.8, (rounds, m)), jnp.float32),
        bw_mult=jnp.ones((rounds, 2), jnp.float32),
        u=jnp.full((rounds, SYS.n_fps - 1), 0.5, jnp.float32),
        arrive_n=jnp.asarray([0, 4, 0], jnp.int32),
        depart=jnp.zeros((rounds, m), bool))

    adjusted = sess.apply_feedback(stream)
    # capacity-weighted scale drops below the admission scarcity threshold
    scale = float(np.asarray(adjusted.bw_scale)[0])
    assert scale < sess.admission.degrade_frac * 1.0, scale
    assert np.all(np.asarray(adjusted.bw_mult)[:, 1] < 1.0)

    base = _session(pools, m, admission=AdmissionConfig(init_alive=4))
    out_blind = base.run(stream)
    sess.reset()
    out_fb = sess.run(adjusted)

    # the 4 streams arriving at round 1 land in slots 4..8; under measured
    # scarcity they are admitted degrade-pinned (r = p = v = 0) while the
    # feedback-blind run serves them at full CCG fidelity
    new = np.s_[1:, 4:]
    assert np.all(np.asarray(out_fb["r"])[new] == 0)
    assert np.any(np.asarray(out_blind["r"])[new] > 0)
    assert not np.array_equal(np.asarray(out_fb["r"]),
                              np.asarray(out_blind["r"]))
    # routing itself stays consistent for the originally alive streams
    np.testing.assert_array_equal(np.asarray(out_fb["alive"]),
                                  np.asarray(out_blind["alive"]))
