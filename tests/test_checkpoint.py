"""Checkpoint manager: roundtrip, retention, elastic re-shard restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, restore, save

TMP = "results/_test_ckpt"


@pytest.fixture(autouse=True)
def _clean():
    shutil.rmtree(TMP, ignore_errors=True)
    os.makedirs(TMP, exist_ok=True)
    yield
    shutil.rmtree(TMP, ignore_errors=True)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_roundtrip_exact():
    tree = _tree()
    save(os.path.join(TMP, "x"), tree, extra={"step": 7})
    out, extra = restore(os.path.join(TMP, "x"), tree)
    assert extra["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_manager_retention_and_latest():
    mgr = CheckpointManager(TMP, keep=2)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step))
    assert mgr.latest_step() == 30
    dirs = sorted(d for d in os.listdir(TMP) if d.startswith("step_"))
    assert dirs == ["step_20", "step_30"]  # step_10 evicted


def test_restore_latest_roundtrip():
    mgr = CheckpointManager(TMP, keep=3)
    t = _tree(1)
    mgr.save(5, t)
    out, extra = mgr.restore_latest(t)
    assert extra["step"] == 5
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))


def test_elastic_reshard_restore():
    """Restore with explicit target shardings (different 'mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree(2)
    save(os.path.join(TMP, "y"), tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
    out, _ = restore(os.path.join(TMP, "y"), tree, shardings=sh)
    for leaf in jax.tree_util.tree_leaves(out):
        assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_corrupt_save_does_not_clobber(monkeypatch):
    """A failed save must leave the previous checkpoint intact (atomicity)."""
    path = os.path.join(TMP, "z")
    tree = _tree(3)
    save(path, tree, extra={"v": 1})

    import repro.checkpoint.manager as mgr

    class Boom(Exception):
        pass

    def bad_packb(*a, **k):
        raise Boom()

    # fail inside the tmp-dir write, regardless of which codec is in use
    monkeypatch.setattr(mgr.msgpack, "packb", bad_packb)
    with pytest.raises(Boom):
        save(path, _tree(4), extra={"v": 2})
    out, extra = restore(path, tree)
    assert extra["v"] == 1
