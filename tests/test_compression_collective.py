"""compressed_allreduce under shard_map on 8 (fake) devices.

Needs its own process: XLA device count locks at first jax init, so the test
spawns a subprocess with --xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.train.compression import compressed_allreduce

    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.3

    def body(xs):
        return compressed_allreduce(xs[0], "data")[None]

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    )(x)
    ref = jnp.sum(x, axis=0)
    got = out[0]
    err = float(jnp.max(jnp.abs(got - ref)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert err <= 8 * scale * 0.51 + 1e-6, (err, scale)
    print("OK", err)
    """
)


@pytest.mark.parametrize("_", [0])
def test_compressed_allreduce_8dev(_, tmp_path):
    script = tmp_path / "collective.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(script)], env=env, cwd=os.getcwd(),
        capture_output=True, text=True, timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
