"""Sharding rules: divisibility fitting, multi-pod adaptation (property-based)."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import SERVE_BASE, TRAIN_BASE, make_rules


def _mesh(multi=False):
    # tiny host mesh stands in; axis names are what matter for specs
    n = len(jax.devices())
    if multi:
        return jax.make_mesh((1, 1, n), ("pod", "data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))


def test_rule_tables_cover_all_logical_axes():
    assert set(SERVE_BASE) == set(TRAIN_BASE)


def test_multi_pod_prepends_pod_to_data():
    mesh = _mesh(multi=True)
    rules = make_rules(mesh, "train")
    spec = rules.spec(("batch",))
    assert spec == P(("pod", "data"))


def test_single_pod_has_no_pod_axis():
    mesh = _mesh(multi=False)
    rules = make_rules(mesh, "train")
    for name in TRAIN_BASE:
        ax = rules.mapping[name]
        axes = (ax,) if isinstance(ax, str) else (ax or ())
        assert "pod" not in axes


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    axes=st.lists(
        st.sampled_from(["batch", "embed", "vocab", "mlp", "experts", None]),
        min_size=1, max_size=4,
    ),
)
def test_fitted_sharding_always_divides(dims, axes):
    n = min(len(dims), len(axes))
    dims, axes = tuple(dims[:n]), tuple(axes[:n])
    mesh = _mesh()
    rules = make_rules(mesh, "train")
    sh = rules.fitted_sharding(mesh, axes, dims)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, entry in zip(dims, tuple(sh.spec) + (None,) * (len(dims) - len(sh.spec))):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        total = int(np.prod([sizes[a] for a in names]))
        assert dim % total == 0, (dim, entry)


def test_no_duplicate_mesh_axes_in_one_spec():
    mesh = _mesh()
    rules = make_rules(mesh, "train")
    spec = rules.spec(("heads_flat", "mlp"))  # both map to "model"
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat += [s] if isinstance(s, str) else list(s)
    assert len(flat) == len(set(flat)), spec


def test_overrides_apply():
    mesh = _mesh()
    rules = make_rules(mesh, "serve", overrides={"experts": None, "expert_mlp": "model"})
    assert rules.spec(("experts",)) == P(None)
    assert rules.spec(("expert_mlp",)) == P("model")


def test_pad_leading_pads_any_axis():
    """pad_leading(axis=) pads exactly the named axis — the sharded serve
    driver uses axis=1 to pad the stream axis of round-stacked (R, M, ...)
    arrays without the moveaxis round-trip."""
    from repro.sharding.compat import pad_leading

    x = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    y = np.asarray(pad_leading(x, 2, axis=1))
    assert y.shape == (2, 5, 4)
    np.testing.assert_array_equal(y[:, :3], x)
    assert (y[:, 3:] == 0).all()
    # default keeps the historical leading-axis behavior
    z = np.asarray(pad_leading(x, 1, value=7.0))
    assert z.shape == (3, 3, 4)
    assert (z[2] == 7.0).all()
