"""Scenario engine tests (paper §4.3 robustness): compiled fault traces,
the ``none``-trace bit-identity, masked-server realization, availability-
aware LPT parity, hedged realization, cluster/runtime edge cases, SimConfig
validation, the elastic serving driver across device loss, and the paper's
robustness claim — r2evid beats every baseline on ``sla_cost`` under
edge_outage AND bw_collapse — asserted against the checked-in goldens."""
import dataclasses
import json
import pathlib
import subprocess
import sys as _sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig
from repro.runtime.cluster import ClusterSim, elastic_remesh
from repro.serving.policy import Observation, make_policy
from repro.serving.scenarios import (SCENARIOS, SUITE, ScenarioTrace,
                                     apply_scenario, compile_scenario,
                                     run_scenario, run_suite,
                                     scenario_metrics)
from repro.serving.session import ServeSession
from repro.serving.simulator import SimConfig, Simulator, _lpt_queue

SYS = SystemConfig()
ROOT = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# SimConfig validation (the silent-fallthrough bugfix)
# ---------------------------------------------------------------------------
def test_simconfig_rejects_out_of_range_fluctuation():
    with pytest.raises(ValueError, match="bw_fluctuation"):
        SimConfig(bw_fluctuation=0.31)
    with pytest.raises(ValueError, match="bw_fluctuation"):
        SimConfig(bw_fluctuation=-0.01)
    SimConfig(bw_fluctuation=0.3)   # boundary is valid


def test_simconfig_rejects_unknown_requirement():
    with pytest.raises(ValueError, match="requirement"):
        SimConfig(requirement="flutcuating")
    SimConfig(requirement="fluctuating")


# ---------------------------------------------------------------------------
# trace compilation: shapes, determinism, registry
# ---------------------------------------------------------------------------
def test_compile_scenario_shapes_and_determinism():
    simc = SimConfig(n_tasks=8, n_rounds=12)
    r, m = 12, 8
    s_tot = simc.n_edge_servers + simc.n_cloud_servers
    for name in SUITE:
        t1 = compile_scenario(name, SYS, simc, seed=3)
        t2 = compile_scenario(name, SYS, simc, seed=3)
        for fld in ("tier_ok", "avail", "bw_mult", "bw_scale", "u", "lat_mult",
                    "arrive_n", "depart"):
            a, b = getattr(t1, fld), getattr(t2, fld)
            assert (a is None) == (b is None), (name, fld)
            if a is not None:
                np.testing.assert_array_equal(a, b, err_msg=f"{name}.{fld}")
        assert t1.hedge == t2.hedge
        assert t1.admission == t2.admission

    eo = compile_scenario("edge_outage", SYS, simc)
    assert eo.tier_ok.shape == (r, 2) and eo.avail.shape == (r, s_tot)
    assert eo.onset == r // 3
    # the cloud tier never goes down in an edge outage
    assert (eo.tier_ok[:, 1] == 1).all() and (eo.avail[:, -1] == 1).all()

    bc = compile_scenario("bw_collapse", SYS, simc)
    assert bc.bw_mult.shape == (r, 2)
    assert (bc.bw_mult[:, 0] == 1).all()            # edge links stay local
    assert bc.bw_mult[:, 1].min() == pytest.approx(0.15)
    assert bc.bw_mult[0, 1] == 1.0 and bc.bw_mult[-1, 1] == 1.0

    st = compile_scenario("straggler_tail", SYS, simc)
    assert st.lat_mult.shape == (r, m, 2)
    assert st.lat_mult.min() >= 1.0 and st.lat_mult.max() <= 20.0
    assert st.hedge == (0.9, 0.05)

    au = compile_scenario("adversarial_u", SYS, simc)
    assert au.u.shape == (r, SYS.num_versions)
    # the Γ budget is saturated every round, rotating across versions
    assert ((au.u > 0).sum(axis=1) == SYS.gamma).all()
    assert not (au.u > 0).all(axis=0).any() or SYS.gamma == SYS.num_versions

    ch = compile_scenario("churn", SYS, simc)
    assert ch.arrive_n.shape == (r,) and ch.arrive_n.dtype == np.int32
    assert ch.depart.shape == (r, m) and ch.depart.dtype == bool
    assert ch.admission is not None and ch.admission.init_alive == m // 2

    fc = compile_scenario("flash_churn", SYS, simc)
    assert fc.arrive_n.max() >= m // 2          # at least one flash burst
    assert fc.bw_mult.shape == (r, 2) and fc.bw_mult.min() == \
        pytest.approx(0.4)
    # the bursts land below degrade_frac: admission must degrade, not admit
    assert fc.bw_scale.min() < fc.admission.degrade_frac
    assert fc.onset is not None and fc.arrive_n[fc.onset] >= m // 2

    mb = compile_scenario("markov_bw", SYS, simc)
    assert mb.bw_mult.shape == (r, 2)
    assert (mb.bw_mult[:, 0] == 1).all()        # edge links stay local
    assert set(np.unique(mb.bw_mult[:, 1])) <= {np.float32(0.3),
                                                np.float32(1.0)}

    oc = compile_scenario("outage_collapse", SYS, simc)
    assert oc.tier_ok.shape == (r, 2) and oc.avail.shape == (r, s_tot)
    assert oc.bw_mult.shape == (r, 2)
    # both faults fire: the edge tier drops AND the cloud uplink collapses
    assert oc.tier_ok[:, 0].min() == 0.0
    assert oc.bw_mult[:, 1].min() == pytest.approx(0.15)
    # the joint budget is tighter than either single-fault trace
    eo2 = compile_scenario("edge_outage", SYS, simc)
    bc2 = compile_scenario("bw_collapse", SYS, simc)
    assert oc.bw_scale.min() < min(eo2.bw_scale.min(), bc2.bw_scale.min())

    with pytest.raises(KeyError, match="unknown scenario"):
        compile_scenario("volcano", SYS, simc)
    assert set(SUITE) | {"none"} == set(SCENARIOS)


def test_apply_scenario_none_is_identity():
    simc = SimConfig(n_tasks=6, n_rounds=4)
    stream = Simulator(SYS, simc).sample_stream(4)
    trace = compile_scenario("none", SYS, simc)
    assert apply_scenario(stream, trace) is stream


def test_apply_scenario_composes_bw_and_replaces_u():
    simc = SimConfig(n_tasks=6, n_rounds=12, bw_fluctuation=0.2, seed=1)
    stream = Simulator(SYS, simc).sample_stream(12)
    bc = compile_scenario("bw_collapse", SYS, simc)
    out = apply_scenario(stream, bc)
    np.testing.assert_allclose(np.asarray(out.bw_mult),
                               np.asarray(stream.bw_mult) * bc.bw_mult,
                               rtol=1e-6)
    au = compile_scenario("adversarial_u", SYS, simc)
    out = apply_scenario(stream, au)
    np.testing.assert_array_equal(np.asarray(out.u), au.u)


# ---------------------------------------------------------------------------
# none-scenario bit-identity with the plain session run
# ---------------------------------------------------------------------------
def test_none_scenario_bit_identical_to_plain_run():
    """`run_scenario(policy, "none")` must lower the exact pre-scenario
    program: every per-round metric array equals the plain ServeSession.run
    bit for bit (same sim seed, same stream)."""
    streams, rounds = 16, 5
    scalars, mets = run_scenario("r2evid", "none", streams=streams,
                                 rounds=rounds, return_mets=True)

    simc = SimConfig(n_tasks=streams, n_rounds=rounds, seed=11,
                     bw_fluctuation=0.2)
    stream = Simulator(SYS, simc).sample_stream(rounds)
    session = ServeSession(make_policy("r2evid", SYS), streams, sim=simc)
    plain = session.run(stream)
    assert set(mets) == set(plain)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(mets[k]),
                                      np.asarray(plain[k]), err_msg=k)
    assert scalars["sla_cost"] == pytest.approx(
        scalars["cost"] + 10.0 * scalars["sla_violation_rate"])
    assert scalars["recovery_rounds"] == 0.0


# ---------------------------------------------------------------------------
# edge outage: no realized segment on a masked tier / server
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["sniper", "r2evid"])
def test_edge_outage_never_realizes_on_masked_tier(policy):
    streams, rounds = 16, 9
    simc = SimConfig(n_tasks=streams, n_rounds=rounds, seed=11,
                     bw_fluctuation=0.2)
    trace = compile_scenario("edge_outage", SYS, simc, rounds, seed=0)
    _, mets = run_scenario(policy, trace, streams=streams, rounds=rounds,
                           return_mets=True)
    route = np.asarray(mets["route"])                       # (R, M)
    masked = trace.tier_ok[:, 0] == 0                       # router-masked
    assert masked.any() and not masked.all()
    assert (route[masked] == 1).all(), \
        "segments realized on the edge tier while it was router-masked"
    # even in all-edge-dead rounds (realization clamp) nothing lands on a
    # dead pool: every metric stays finite (a dead-server LPT placement
    # would produce inf queue delay)
    assert np.isfinite(np.asarray(mets["delay"])).all()
    assert np.isfinite(np.asarray(mets["cost"])).all()
    # pre-onset rounds are untouched: some edge traffic exists for an
    # edge-using policy
    assert (route[:trace.onset] == 0).any()


def test_lpt_queue_avail_parity_with_reduced_pool():
    """Masking servers [1, 3] out of a 5-edge/2-cloud pool must pack
    exactly like a physical 3-edge/1-cloud pool (argmin order preserved),
    and a fully-dead tier shows up as inf queue delay — the sentinel the
    route clamp exists to make unreachable."""
    rng = np.random.default_rng(5)
    m = 24
    t_comp = jnp.asarray(rng.uniform(0.1, 2.0, m), jnp.float32)
    route = jnp.asarray((rng.uniform(size=m) < 0.4).astype(np.int32))
    avail = jnp.asarray([1, 0, 1, 0, 1, 1, 0], jnp.float32)
    q_masked = _lpt_queue(t_comp, route, 5, 2, avail)
    q_small = _lpt_queue(t_comp, route, 3, 1)
    np.testing.assert_array_equal(np.asarray(q_masked), np.asarray(q_small))

    # batched leading dim works too
    tb = jnp.stack([t_comp, t_comp * 2.0])
    rb = jnp.stack([route, route])
    ab = jnp.stack([avail, avail])
    qb = _lpt_queue(tb, rb, 5, 2, ab)
    np.testing.assert_array_equal(np.asarray(qb[0]), np.asarray(q_small))

    dead_edge = jnp.asarray([0, 0, 0, 0, 0, 1, 1], jnp.float32)
    q_dead = np.asarray(_lpt_queue(t_comp, route, 5, 2, dead_edge))
    edge_tasks = np.asarray(route) == 0
    assert np.isinf(q_dead[edge_tasks]).all()
    assert np.isfinite(q_dead[~edge_tasks]).all()


# ---------------------------------------------------------------------------
# hedged realization inside the scan
# ---------------------------------------------------------------------------
def test_straggler_tail_hedging_cuts_delay():
    streams, rounds = 16, 6
    simc = SimConfig(n_tasks=streams, n_rounds=rounds, seed=11,
                     bw_fluctuation=0.2)
    trace = compile_scenario("straggler_tail", SYS, simc, rounds, seed=0)
    assert trace.hedge is not None
    _, hedged = run_scenario("sniper", trace, streams=streams, rounds=rounds,
                             return_mets=True)
    unhedged_trace = dataclasses.replace(trace, hedge=None)
    _, plain = run_scenario("sniper", unhedged_trace, streams=streams,
                            rounds=rounds, return_mets=True)
    d_h = np.asarray(hedged["delay"])
    d_p = np.asarray(plain["delay"])
    # the backup race can only help (min with the primary), and with a
    # Pareto tail it strictly helps somewhere
    assert (d_h <= d_p + 1e-6).all()
    assert d_h.mean() < d_p.mean()
    assert d_h.max() < d_p.max()


def test_session_rejects_bad_hedge():
    simc = SimConfig(n_tasks=4, n_rounds=2)
    with pytest.raises(ValueError):
        ServeSession(make_policy("sniper", SYS), 4, sim=simc, hedge=(1.5, 0.1))


# ---------------------------------------------------------------------------
# scenario metrics
# ---------------------------------------------------------------------------
def test_scenario_metrics_recovery_rounds():
    r, m = 10, 4
    cost = np.ones((r, m), np.float32)
    cost[3:6] = 5.0                       # degraded rounds 3..5
    acc = np.full((r, m), 0.9, np.float32)
    acc[0, 0] = 0.1                       # one SLA miss
    mets = {"cost": cost, "delay": cost, "accuracy": acc,
            "route": np.zeros((r, m), np.float32)}
    stream = Observation(z=jnp.zeros((r, m)), aq=jnp.full((r, m), 0.6))
    trace = ScenarioTrace(name="synthetic", onset=3)
    out = scenario_metrics(mets, stream, trace)
    assert out["recovery_rounds"] == 3.0          # recovered at round 6
    assert out["sla_violation_rate"] == pytest.approx(1.0 / (r * m))
    assert out["sla_cost"] == pytest.approx(out["cost"] + 10.0 / (r * m))

    # never recovers -> R - onset
    cost_bad = np.ones((r, m), np.float32)
    cost_bad[3:] = 5.0
    out = scenario_metrics(dict(mets, cost=cost_bad), stream, trace)
    assert out["recovery_rounds"] == float(r - 3)


# ---------------------------------------------------------------------------
# cluster runtime edge cases
# ---------------------------------------------------------------------------
def test_cluster_kill_is_idempotent_and_tick_survives_total_failure():
    c = ClusterSim(3, heartbeat_timeout=1.0)
    c.kill(1)
    assert c.alive == 2
    c.kill(1)                              # killing a dead node: no-op
    assert c.alive == 2
    c.kill(0)
    c.kill(2)
    assert c.alive == 0
    # ticking a fully-dead cluster with no heartbeats must not resurrect or
    # re-kill anyone
    assert c.tick(dt=5.0, heartbeats=set()) == set()
    assert c.alive == 0 and c.dead == {0, 1, 2}


def test_cluster_tick_detects_silent_nodes():
    c = ClusterSim(2, heartbeat_timeout=1.0)
    assert c.tick(dt=1.0, heartbeats={0}) == set()     # within timeout
    assert c.tick(dt=1.0, heartbeats={0}) == {1}       # node 1 silent > 1s
    assert c.alive == 1


def test_elastic_remesh_validation():
    with pytest.raises(ValueError, match="at least one surviving device"):
        elastic_remesh(0)
    with pytest.raises(ValueError, match="at least one surviving device"):
        elastic_remesh(-2)
    with pytest.raises(ValueError, match="prefer"):
        elastic_remesh(1, prefer="diagonal")
    mesh = elastic_remesh(1, prefer="data")
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1


def test_run_elastic_matches_dense_across_device_loss():
    """4 fake host devices; nodes {1, 3} die before round 4.  The elastic
    driver re-meshes (4,1) -> (2,1) mid-run and must reproduce the dense
    single-device run's metrics (subprocess: device count locks at first
    jax init — same idiom as tests/test_engine_scan.py)."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.serving.policy import make_policy
        from repro.serving.session import ServeSession
        from repro.serving.simulator import SimConfig, Simulator

        sys_ = SystemConfig()
        simc = SimConfig(n_tasks=16, n_rounds=8, seed=11, bw_fluctuation=0.2)
        stream = Simulator(sys_, simc).sample_stream(8)

        dense = ServeSession(make_policy("r2evid", sys_), 16, sim=simc)
        mets_d = dense.run(stream)

        el = ServeSession(make_policy("r2evid", sys_), 16, sim=simc)
        mets_e = el.run_elastic(stream, {4: [1, 3]})
        assert [m.shape["data"] for _, m in el.mesh_history] == [4, 2], \\
            el.mesh_history
        for k in mets_d:
            np.testing.assert_allclose(
                np.asarray(mets_e[k]), np.asarray(mets_d[k]),
                atol=1e-5, rtol=1e-5, err_msg=k)
        print("OK")
        """
    )
    out = subprocess.run([_sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# the paper's robustness claim + golden suite
# ---------------------------------------------------------------------------
def test_r2evid_beats_baselines_under_degradation_and_matches_goldens():
    """The Table-2 generalization at the golden operating point (M=64,
    R=30): r2evid's SLA-adjusted cost beats EVERY registered baseline on
    both edge_outage and bw_collapse, and every computed cell matches the
    checked-in SCENARIO_GOLDENS.json."""
    rows = run_suite(scenarios=("edge_outage", "bw_collapse"))
    for scen in ("edge_outage", "bw_collapse"):
        ours = rows[f"r2evid@{scen}"]["sla_cost"]
        for pol in ("a2_cloud_only", "jcab", "rdap", "sniper"):
            theirs = rows[f"{pol}@{scen}"]["sla_cost"]
            assert ours < theirs, (
                f"r2evid sla_cost {ours:.3f} not better than {pol} "
                f"{theirs:.3f} under {scen}")

    gold_path = ROOT / "SCENARIO_GOLDENS.json"
    assert gold_path.exists(), "run benchmarks/scenario_suite.py --write"
    gold = json.loads(gold_path.read_text())["rows"]
    for key, scalars in rows.items():
        assert key in gold, f"{key} missing from SCENARIO_GOLDENS.json"
        for metric, val in scalars.items():
            np.testing.assert_allclose(
                val, gold[key][metric], rtol=2e-3, atol=2e-3,
                err_msg=f"{key}:{metric}")


def test_r2evid_recovery_slo_under_correlated_faults_matches_goldens():
    """The correlated regime (edge outage + cloud bw collapse co-occurring)
    at the golden operating point: r2evid keeps its SLA-cost standing over
    the cloud-pinned baseline AND recovers no slower than the checked-in
    ``recovery_rounds`` SLO — the per-policy recovery golden is the gate,
    not just the cost table."""
    ours = run_scenario("r2evid", "outage_collapse")
    base = run_scenario("a2_cloud_only", "outage_collapse")
    assert ours["sla_cost"] < base["sla_cost"], (
        f"r2evid sla_cost {ours['sla_cost']:.3f} not better than "
        f"a2_cloud_only {base['sla_cost']:.3f} under outage_collapse")
    gold = json.loads((ROOT / "SCENARIO_GOLDENS.json").read_text())["rows"]
    g = gold["r2evid@outage_collapse"]
    assert ours["recovery_rounds"] <= g["recovery_rounds"] + 1e-6, (
        f"r2evid recovery_rounds regressed: {ours['recovery_rounds']} vs "
        f"golden SLO {g['recovery_rounds']}")
    for metric in ("cost", "sla_cost", "sla_violation_rate",
                   "recovery_rounds"):
        np.testing.assert_allclose(ours[metric], g[metric], rtol=2e-3,
                                   atol=2e-3, err_msg=metric)
