"""MoE dispatch: gather/scatter grouped-matmul vs. a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.layers import Ctx
from repro.models.moe import moe_forward, moe_specs
from repro.models.params import init_params


def _naive_moe(p, x, cfg):
    """Per-token loop oracle (no capacity, exact top-k mixture)."""
    e = cfg.moe
    b, s, d = x.shape
    xt = np.asarray(x.reshape(b * s, d), np.float32)
    router = np.asarray(p["router"], np.float32)
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    logits = xt @ router
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-logits[t])[: e.top_k]
        w = np.exp(logits[t, top] - logits[t, top].max())
        w = w / w.sum()
        for wi, ei in zip(w, top):
            g = xt[t] @ wg[ei]
            u = xt[t] @ wu[ei]
            h = (g / (1 + np.exp(-g))) * u  # silu(g) * u
            out[t] += wi * (h @ wd[ei])
    return out.reshape(b, s, d)


@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "mixtral-8x22b"])
def test_moe_matches_naive_oracle(arch):
    cfg = get_smoke_config(arch)  # capacity_factor=8 => no drops
    ctx = Ctx(cfg=cfg)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    # force fp32 compute for the comparison
    import dataclasses
    ctx32 = Ctx(cfg=dataclasses.replace(cfg, compute_dtype="float32"))
    y, aux = moe_forward(ctx32, p, x)
    y_ref = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-3)
    assert float(aux) > 0  # load-balance loss is positive


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0, dropped tokens produce zero expert output
    but the layer stays finite and shaped."""
    import dataclasses
    from repro.models.config import MoEConfig

    cfg = get_smoke_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                           capacity_factor=1.0, min_capacity=1)
    )
    ctx = Ctx(cfg=cfg)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.bfloat16)
    y, _ = moe_forward(ctx, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y.astype(jnp.float32)).all()


def test_int8_expert_quantization():
    """Serve-time int8 expert weights: numerics within int8 tolerance and
    spec tree carries int8 storage + scales."""
    import dataclasses
    from repro.models.moe import quantize_expert_params

    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x22b"), compute_dtype="float32",
        quant_experts_serve=True,
    )
    p32 = init_params(moe_specs(cfg), jax.random.PRNGKey(0), dtype_override=jnp.float32)
    pq = quantize_expert_params(p32)
    assert pq["w_gate"].dtype == jnp.int8
    ctx = Ctx(cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y32, _ = moe_forward(ctx, p32, x)
    yq, _ = moe_forward(ctx, pq, x)
    rel = float(jnp.max(jnp.abs(yq - y32))) / float(jnp.max(jnp.abs(y32)))
    assert rel < 0.05, rel
    # quantized serve specs carry int8 weights + scale leaves
    qspecs = moe_specs(cfg, quantized=True)
    assert qspecs["w_gate"].dtype == jnp.int8
    assert "w_gate_scale" in qspecs


def test_moe_grad_flows():
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    ctx = Ctx(cfg=cfg)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))

    def loss(p_):
        x = jnp.ones((1, 8, cfg.d_model), jnp.bfloat16) * 0.1
        y, aux = moe_forward(ctx, p_, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + 0.01 * aux

    grads = jax.grad(loss)(p)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
