"""Slot-pool churn tests: the compiled churn scan vs a host-loop oracle
(bit-identity), none-churn bit-identity with the fixed-M session, alive-lane
parity with a compacted dense run, the admission controller's provable
budget bound under flash-crowd arrivals, masked-lane invariants (no segment
on a dead slot or freed server), the sharded churn path, and the
malformed-failures / empty-batch regression fixes."""
import dataclasses
import subprocess
import sys as _sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig
from repro.serving.policy import make_policy
from repro.serving.scenarios import apply_scenario, compile_scenario
from repro.serving.session import (AdmissionConfig, ServeSession,
                                   _churn_round)
from repro.serving.simulator import SimConfig, Simulator

SYS = SystemConfig()
M, R = 16, 10


def _stream(m=M, r=R, seed=5):
    simc = SimConfig(n_tasks=m, n_rounds=r, seed=seed, bw_fluctuation=0.2)
    return simc, Simulator(SYS, simc).sample_stream(r)


def _churn_stream(m=M, r=R, seed=5, churn_seed=0, p_dep=0.15, lam=2.0):
    simc, stream = _stream(m, r, seed)
    rng = np.random.default_rng(churn_seed)
    return simc, dataclasses.replace(
        stream,
        arrive_n=jnp.asarray(rng.poisson(lam, size=r), jnp.int32),
        depart=jnp.asarray(rng.random((r, m)) < p_dep))


# ---------------------------------------------------------------------------
# bit-identity: none-churn == plain fixed-M run
# ---------------------------------------------------------------------------
def test_none_churn_bit_identical_to_plain_run():
    """A full pool with zero arrivals and zero departures must reproduce
    the plain (churn-free) session run bit for bit — the slot-pool carry
    is pure overhead along that path, never a perturbation."""
    simc, stream = _stream()
    nochurn = dataclasses.replace(
        stream, arrive_n=jnp.zeros((R,), jnp.int32),
        depart=jnp.zeros((R, M), bool))
    policy = make_policy("r2evid", SYS)
    plain = ServeSession(policy, M, sim=simc).run(stream)
    churn = ServeSession(policy, M, sim=simc,
                         admission=AdmissionConfig()).run(nochurn)
    assert np.asarray(churn["alive"]).all()
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(churn[k]), err_msg=k)


# ---------------------------------------------------------------------------
# bit-identity: compiled scan == host-loop oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["rdap", "r2evid"])
def test_churn_scan_bit_identical_to_host_loop_oracle(name):
    """The whole churned run is ONE ``lax.scan``; a host loop jitting the
    SAME per-round body (``_churn_round``) round by round must agree bit
    for bit — including which slots are alive, the queue depth, and every
    masked metric."""
    simc, cstream = _churn_stream()
    policy = make_policy(name, SYS)
    acfg = AdmissionConfig(init_alive=M // 2)

    sess = ServeSession(policy, M, sim=simc, admission=acfg)
    mets = sess.run(cstream)

    sys_ = policy.lat.sys
    bw_floor = policy.lat.bw[0, 0, :].max()
    total_bw = jnp.asarray(sys_.total_bw_mbps, jnp.float32)
    valid = jnp.ones((M,), bool)
    step = jax.jit(partial(_churn_round, policy, sys_, bw_floor, total_bw,
                           acfg, simc.n_edge_servers, simc.n_cloud_servers,
                           valid))
    carry = (policy.init(M), jnp.arange(M) < M // 2,
             jnp.zeros((M,), bool), jnp.zeros((), jnp.int32))
    rows = []
    for t in range(R):
        obs_t = jax.tree_util.tree_map(lambda x: x[t], cstream)
        carry, out = step(carry, obs_t)
        rows.append(out)
    for k in mets:
        oracle = np.stack([np.asarray(row[k]) for row in rows])
        np.testing.assert_array_equal(np.asarray(mets[k]), oracle,
                                      err_msg=k)


# ---------------------------------------------------------------------------
# alive-lane parity with a compacted dense run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["rdap", "r2evid"])
def test_constant_pool_matches_compacted_dense_run(name):
    """With a constant half-full pool (no churn events) the masked-lane
    arithmetic must equal physically removing the dead slots: a dense
    M/2-stream session on the sliced stream reproduces the alive lanes'
    metrics.  This is the oracle for `where(mask, x, 0)` == compaction."""
    k = M // 2
    simc, stream = _stream()
    frozen = dataclasses.replace(
        stream, arrive_n=jnp.zeros((R,), jnp.int32),
        depart=jnp.zeros((R, M), bool))
    policy = make_policy(name, SYS)
    churn = ServeSession(policy, M, sim=simc,
                         admission=AdmissionConfig(init_alive=k)).run(frozen)
    alive = np.asarray(churn["alive"])
    assert (alive == (np.arange(M) < k)[None, :]).all()

    slim = jax.tree_util.tree_map(
        lambda x: x[:, :k] if hasattr(x, "ndim") and x.ndim >= 2
        and x.shape[1] == M else x, stream)
    simc_k = dataclasses.replace(simc, n_tasks=k)
    dense = ServeSession(policy, k, sim=simc_k).run(slim)
    for key in dense:
        np.testing.assert_allclose(
            np.asarray(churn[key])[:, :k], np.asarray(dense[key]),
            atol=1e-6, rtol=1e-6, err_msg=key)
    # the vacant half never realizes anything
    for key in ("cost", "delay", "energy", "accuracy"):
        assert (np.asarray(churn[key])[:, k:] == 0.0).all(), key
    assert (np.asarray(churn["route"])[:, k:] == -1).all()


# ---------------------------------------------------------------------------
# admission controller: the provable budget bound
# ---------------------------------------------------------------------------
def test_admission_respects_budget_under_flash_crowd():
    """Flash-crowd arrivals against a co-timed bandwidth dip: every round
    that admits must leave the pool feasible at minimum fidelity
    (``n_alive * bw_floor <= budget * (1 - margin)``) — zero
    admitted-then-infeasible segments — and the overflow queue stays
    within ``max_queue`` with non-negative drops."""
    simc, stream = _stream()
    trace = compile_scenario("flash_churn", SYS, simc, R, seed=0)
    degraded = apply_scenario(stream, trace)
    policy = make_policy("r2evid", SYS)
    acfg = trace.admission
    mets = ServeSession(policy, M, sim=simc, admission=acfg).run(degraded)

    bw_floor = float(policy.lat.bw[0, 0, :].max())
    budget = float(SYS.total_bw_mbps) * np.asarray(trace.bw_scale)
    alive_n = np.asarray(mets["alive"]).sum(axis=1)
    admitted = np.asarray(mets["admitted"])
    queue = np.asarray(mets["queue_depth"])
    dropped = np.asarray(mets["dropped"])

    adm_rounds = admitted > 0
    assert adm_rounds.any()                      # the crowd does arrive
    assert (alive_n[adm_rounds] * bw_floor
            <= budget[adm_rounds] * (1.0 - acfg.margin) + 1e-4).all(), (
        "admission overflowed the round budget")
    assert (queue <= acfg.max_queue).all()
    assert (dropped >= 0).all()
    assert (queue > 0).any()                     # backpressure was exercised
    # scarcity rounds admit at pinned minimum fidelity only
    scarce = budget < acfg.degrade_frac * float(SYS.total_bw_mbps)
    assert scarce.any()


def test_degrade_pins_hold_minimum_fidelity():
    """A stream admitted while capacity is scarce serves at (r=p=v=0) for
    its whole pool lifetime, even after bandwidth recovers."""
    simc, stream = _stream()
    r0 = 3
    bw = np.ones((R,), np.float32)
    bw[r0:r0 + 2] = 0.3                          # scarcity window
    arrive = np.zeros((R,), np.int32)
    arrive[r0] = 4                               # admitted under scarcity
    degraded = dataclasses.replace(
        stream,
        bw_scale=jnp.asarray(bw),
        arrive_n=jnp.asarray(arrive),
        depart=jnp.zeros((R, M), bool))
    k = M - 6
    mets = ServeSession(
        make_policy("rdap", SYS), M, sim=simc,
        admission=AdmissionConfig(init_alive=k)).run(degraded)
    alive = np.asarray(mets["alive"])
    # the burst landed (scarce budget still fits a few min-fidelity lanes)
    newly = alive[r0] & ~alive[r0 - 1]
    assert newly.any()
    for key in ("r", "p", "v"):
        vals = np.asarray(mets[key])[r0:, newly]
        assert (vals == 0).all(), f"{key} escaped the degrade pin"


# ---------------------------------------------------------------------------
# masked-lane invariants: dead slots and freed servers
# ---------------------------------------------------------------------------
def test_no_segment_lands_on_dead_slot_or_downed_tier():
    """Churn composed with an edge outage: dead slots never realize
    (route=-1, zero metrics) and no *alive* lane routes to the outaged
    tier while its quorum gate is down."""
    simc, cstream = _churn_stream()
    trace = compile_scenario("edge_outage", SYS, simc, R, seed=0)
    degraded = apply_scenario(cstream, trace)
    mets = ServeSession(
        make_policy("r2evid", SYS), M, sim=simc,
        admission=AdmissionConfig(init_alive=M // 2)).run(degraded)
    alive = np.asarray(mets["alive"])
    route = np.asarray(mets["route"])
    assert (route[~alive] == -1).all()
    for key in ("cost", "delay", "energy", "accuracy"):
        vals = np.asarray(mets[key])
        assert (vals[~alive] == 0.0).all(), key
        assert np.isfinite(vals).all(), key
    edge_down = np.asarray(trace.tier_ok)[:, 0] == 0.0
    assert edge_down.any()
    assert (route[edge_down] != 0).all(), \
        "a segment landed on the outaged edge tier"


# ---------------------------------------------------------------------------
# sharded churn path
# ---------------------------------------------------------------------------
def test_sharded_churn_matches_dense():
    """4 fake host devices: the sharded churn scan (replicated admission,
    locally-sliced slot resets) agrees with the dense churn run
    (subprocess: device count locks at first jax init)."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.serving.policy import make_policy
        from repro.serving.session import AdmissionConfig, ServeSession
        from repro.serving.simulator import SimConfig, Simulator

        sys_ = SystemConfig()
        m, r = 16, 8
        simc = SimConfig(n_tasks=m, n_rounds=r, seed=11, bw_fluctuation=0.2)
        stream = Simulator(sys_, simc).sample_stream(r)
        rng = np.random.default_rng(0)
        stream = dataclasses.replace(
            stream,
            arrive_n=jnp.asarray(rng.poisson(2.0, size=r), jnp.int32),
            depart=jnp.asarray(rng.random((r, m)) < 0.15))

        acfg = AdmissionConfig(init_alive=m // 2)
        pol = make_policy("rdap", sys_)
        dense = ServeSession(pol, m, sim=simc, admission=acfg).run(stream)
        mesh = jax.make_mesh((4,), ("data",))
        sess = ServeSession(pol, m, sim=simc, admission=acfg)
        shard = sess.run_sharded(mesh, stream)
        assert set(dense) == set(shard)
        for k in dense:
            np.testing.assert_allclose(
                np.asarray(dense[k]), np.asarray(shard[k]),
                atol=1e-5, rtol=1e-5, err_msg=k)
        print("OK")
        """
    )
    out = subprocess.run([_sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_churn_requires_admission_config_and_both_traces():
    simc, cstream = _churn_stream()
    sess = ServeSession(make_policy("rdap", SYS), M, sim=simc)
    with pytest.raises(ValueError, match="AdmissionConfig"):
        sess.run(cstream)
    half = dataclasses.replace(cstream, depart=None)
    sess2 = ServeSession(make_policy("rdap", SYS), M, sim=simc,
                         admission=AdmissionConfig())
    with pytest.raises(ValueError, match="BOTH"):
        sess2.run(half)


def test_churn_rejects_hedge():
    simc, cstream = _churn_stream()
    sess = ServeSession(make_policy("rdap", SYS), M, sim=simc,
                        admission=AdmissionConfig(), hedge=(0.9, 0.05))
    with pytest.raises(ValueError, match="hedge"):
        sess.run(cstream)


# ---------------------------------------------------------------------------
# regression: malformed failure plans must raise, not shrink the experiment
# ---------------------------------------------------------------------------
def test_run_elastic_rejects_malformed_failures():
    simc, stream = _stream()
    sess = ServeSession(make_policy("r2evid", SYS), M, sim=simc)
    with pytest.raises(ValueError, match="round 0"):
        sess.run_elastic(stream, {0: [1]})
    with pytest.raises(ValueError, match=f"1..{R - 1}"):
        sess.run_elastic(stream, {R: [1]})
    with pytest.raises(ValueError, match="unknown node 99"):
        sess.run_elastic(stream, {2: [99]}, n_nodes=4)


# ---------------------------------------------------------------------------
# regression: an empty routed batch is a no-op, not a crash
# ---------------------------------------------------------------------------
def test_model_pool_serves_empty_batch():
    from repro.configs import get_smoke_config
    from repro.serving.pools import ModelPool

    pool = ModelPool(get_smoke_config("qwen1.5-0.5b"),
                     jax.random.PRNGKey(0), name="edge")
    out = pool.serve_segment(jnp.zeros((0, 16), jnp.int32), decode_tokens=4)
    assert out.shape == (0, 4) and out.dtype == jnp.int32
    assert pool.stats.requests == 0 and pool.stats.tokens == 0
