"""Hierarchical sharded serving: the O(n_devices) cross-task tail.

Covers the C6 sub-budget algebra (exact budget conservation, headroom
shards untouched, n=1 degeneracy), the hierarchical-vs-dense repair oracle
on slack-carrying solutions (exact C6 satisfaction, per-shard target
satisfaction, per-task demotion gap <= ONE level, feasibility preserved),
1-device bit-identity of the whole sharded run for every policy, the jaxpr
collective audit (no (M,)-sized operand crosses devices inside the
hierarchical round body), the guard rails, and the multi-device subprocess
suites: 8-device decision parity + the measured collective footprint,
churn x outage_collapse x uneven M, and sniper's replicated-profile path.
"""
import dataclasses
import subprocess
import sys as _sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig, accuracy_table
from repro.core.robust import RobustProblem
from repro.core.router import enforce_bandwidth, subbudget_from_stats
from repro.serving.policy import make_policy
from repro.serving.session import ServeSession, _serve_run_sharded
from repro.serving.simulator import SimConfig, Simulator
from repro.sharding.audit import collective_footprint

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)
LAT = PROB.lat


# ---------------------------------------------------------------------------
# C6 sub-budget algebra (pure, no mesh)
# ---------------------------------------------------------------------------
def test_subbudget_conserves_exactly():
    """sum(target_d) == min(sum(bw_d), B): the per-shard sub-budgets hand
    out exactly the global C6 budget when it binds and exactly the current
    draw when it does not — no bandwidth is ever lost or invented."""
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 4, 8):
        for _ in range(8):
            bw = jnp.asarray(rng.uniform(0.0, 100.0, n), jnp.float32)
            w = jnp.asarray(rng.integers(1, 9, n), jnp.float32)
            budget = float(rng.uniform(10.0, 500.0))
            t = np.asarray(subbudget_from_stats(bw, w, budget), np.float64)
            want = min(float(np.asarray(bw, np.float64).sum()), budget)
            np.testing.assert_allclose(t.sum(), want, rtol=1e-5)


def test_subbudget_noop_under_budget():
    """With global slack the targets ARE the current draws, bit for bit —
    no shard is asked to demote anything."""
    bw = jnp.asarray([10.0, 25.0, 5.0], jnp.float32)
    w = jnp.asarray([4.0, 4.0, 2.0], jnp.float32)
    t = subbudget_from_stats(bw, w, 100.0)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(bw))


def test_subbudget_only_excess_shards_demote():
    """The whole shortfall lands on shards drawing above their fair share;
    a shard under its fair share keeps its full draw (headroom shards are
    never demoted)."""
    bw = jnp.asarray([10.0, 90.0], jnp.float32)
    w = jnp.asarray([1.0, 1.0], jnp.float32)
    t = np.asarray(subbudget_from_stats(bw, w, 80.0))
    np.testing.assert_allclose(t, [10.0, 70.0], rtol=1e-6)


def test_subbudget_single_shard_degenerates_to_dense():
    """n_devices=1: target == min(bw, B) — the dense repair budget, which
    is what makes the 1-device sharded run bit-identical to dense."""
    for bw, b in ((50.0, 80.0), (120.0, 80.0)):
        t = float(np.asarray(subbudget_from_stats(
            jnp.asarray([bw], jnp.float32), jnp.asarray([7.0], jnp.float32),
            b))[0])
        assert abs(t - min(bw, b)) < 1e-5


# ---------------------------------------------------------------------------
# hierarchical repair vs the dense oracle (slack-carrying solutions)
# ---------------------------------------------------------------------------
def _inflated(m=32, seed=5):
    """Max-fidelity configs with loose requirements: real demotion slack.
    (CCG solutions are cost-minimal, so serve-level repair is a documented
    no-op on them — see test_router.test_enforce_bandwidth_noop_on_ccg...)"""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.uniform(0.1, 0.6, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.6, m), jnp.float32)
    sol = {
        "route": jnp.zeros((m,), jnp.int32),
        "r": jnp.full((m,), SYS.n_res - 1, jnp.int32),
        "p": jnp.full((m,), SYS.n_fps - 1, jnp.int32),
        "v": jnp.full((m,), SYS.num_versions - 1, jnp.int32),
    }
    return z, aq, sol


def _hier_repair(sol, z, aq, budget, n_dev, rounds=64):
    """The hierarchical C6 program, spelled as a host loop over shards:
    per-shard draw/weight stats -> scalar sub-budget split -> per-shard
    dense repair against its own target.  Exactly what repair_local runs
    under shard_map, minus the mesh."""
    m = z.shape[0]
    ml = m // n_dev
    bw = np.asarray(LAT.solution_bandwidth(sol))
    bwd = jnp.asarray([bw[d * ml:(d + 1) * ml].sum() for d in range(n_dev)],
                      jnp.float32)
    w = jnp.full((n_dev,), ml, jnp.float32)
    targets = np.asarray(subbudget_from_stats(bwd, w, budget))
    parts = []
    for d in range(n_dev):
        sl = slice(d * ml, (d + 1) * ml)
        sub = {k: v[sl] for k, v in sol.items()}
        fixed, _ = enforce_bandwidth(SYS, sub, z[sl], aq[sl],
                                     total_budget=float(targets[d]),
                                     rounds=rounds)
        parts.append(fixed)
    return {k: jnp.concatenate([p[k] for p in parts]) for k in sol}, targets


def _demotion_depth(sol):
    return ((SYS.n_res - 1 - np.asarray(sol["r"]))
            + (SYS.n_fps - 1 - np.asarray(sol["p"]))
            + (SYS.num_versions - 1 - np.asarray(sol["v"])))


@pytest.mark.parametrize("n_dev", [2, 4, 8])
def test_hier_repair_exact_c6_and_one_level_gap(n_dev):
    """The tentpole contract, on a binding budget (half the start draw):

    * the hierarchical result meets the GLOBAL C6 budget exactly,
    * every shard meets its own sub-budget,
    * per task the demotion depth differs from the dense oracle by at most
      ONE level,
    * every demoted task stays feasible (accuracy >= aq + robust margin).
    """
    z, aq, sol = _inflated()
    start = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    budget = 0.5 * start
    dense, _ = enforce_bandwidth(SYS, sol, z, aq, total_budget=budget,
                                 rounds=64)
    dense_bw = float(np.asarray(LAT.solution_bandwidth(dense)).sum())
    assert dense_bw <= budget + 1e-4           # the oracle itself binds
    assert _demotion_depth(dense).sum() > 0    # ... by actually demoting

    hier, targets = _hier_repair(sol, z, aq, budget, n_dev)
    hier_bw = float(np.asarray(LAT.solution_bandwidth(hier)).sum())
    assert hier_bw <= budget + 1e-4            # exact global C6
    assert targets.sum() <= budget + 1e-4      # sub-budgets conserve
    ml = z.shape[0] // n_dev
    for d in range(n_dev):                     # per-shard satisfaction
        sub = {k: v[d * ml:(d + 1) * ml] for k, v in hier.items()}
        sbw = float(np.asarray(LAT.solution_bandwidth(sub)).sum())
        assert sbw <= targets[d] + 1e-4, (d, sbw, targets[d])

    gap = np.abs(_demotion_depth(dense) - _demotion_depth(hier))
    assert gap.max() <= 1, gap

    f = np.asarray(accuracy_table(SYS, z))
    idx = np.arange(z.shape[0])
    acc = f[idx, np.asarray(hier["r"]), np.asarray(hier["p"]),
            np.asarray(hier["v"]), np.asarray(hier["route"])]
    assert np.all(acc >= np.asarray(aq) + SYS.acc_margin_robust - 1e-6)


# ---------------------------------------------------------------------------
# full sharded run: 1-device bit-identity + collective audit + guards
# ---------------------------------------------------------------------------
def _serve_stream(m, r, seed=7, bw_scale=0.45):
    simc = SimConfig(n_tasks=m, n_rounds=r, seed=seed, bw_fluctuation=0.15)
    stream = Simulator(SYS, simc).sample_stream(r)
    if bw_scale is not None:   # make the C6 repair budget bind
        stream = dataclasses.replace(
            stream, bw_scale=jnp.full((r,), bw_scale, jnp.float32))
    return simc, stream


@pytest.mark.parametrize(
    "name", ["r2evid", "rdap", "jcab", "a2_cloud_only", "sniper"])
def test_one_device_hierarchical_bit_identical(name):
    """n_devices=1: the hierarchical tail degenerates to the dense program
    (sub-budget == min(bw, B), partitioned pool == the whole pool) — every
    metric bit-identical for every registered policy, sniper included."""
    simc, stream = _serve_stream(m=12, r=5, seed=3)
    pol = make_policy(name, SYS)
    dense = ServeSession(pol, 12, sim=simc).run(stream)
    mesh = jax.make_mesh((1,), ("data",))
    hier = ServeSession(pol, 12, sim=simc, hierarchical=True).run_sharded(
        mesh, stream)
    assert set(dense) == set(hier)
    for k in dense:
        np.testing.assert_array_equal(np.asarray(dense[k]),
                                      np.asarray(hier[k]), err_msg=k)


def test_round_body_collectives_are_device_count_sized():
    """The structural invariant, measured on the jaxpr: inside the scan
    body the hierarchical mode moves only the (2,)-stat gather and the
    2-int psum across devices, while the gathered oracle moves
    m_local-sized arrays.  One stray all_gather of a per-task array fails
    this test."""
    m = 24
    simc, stream = _serve_stream(m=m, r=3)
    pol = make_policy("r2evid", SYS)
    mesh = jax.make_mesh((1,), ("data",))
    state = pol.init(m)

    def footprint(hier):
        return collective_footprint(
            lambda st, obs: _serve_run_sharded(
                pol, st, obs, simc.n_edge_servers, simc.n_cloud_servers,
                mesh, "data", stream.dx is not None, None, None, None, hier),
            state, stream)

    hier_loop = [s for _, s, in_loop in footprint(True) if in_loop]
    assert hier_loop, "hierarchical round body exchanges no stats at all?"
    assert max(hier_loop) <= 4, hier_loop
    gath_loop = [s for name, s, in_loop in footprint(False)
                 if in_loop and "all_gather" in name]
    assert max(gath_loop) >= m, gath_loop


def test_hierarchical_rejects_hedge():
    """The hedge deadline quantile is a global order statistic — the
    hierarchical mode must refuse it loudly, not approximate it."""
    simc, stream = _serve_stream(m=8, r=2, bw_scale=None)
    sess = ServeSession(make_policy("rdap", SYS), 8, sim=simc,
                        hedge=(0.9, 0.05), hierarchical=True)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="hedge"):
        sess.run_sharded(mesh, stream)


# ---------------------------------------------------------------------------
# multi-device subprocess suites (device count locks at first jax init)
# ---------------------------------------------------------------------------
def _run_sub(script, timeout=600):
    out = subprocess.run([_sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout, out.stdout[-1000:]


def test_eight_device_decision_parity_and_footprint():
    """8 fake devices, M=64, pools 16/8: the gathered oracle reproduces
    dense on every key; the hierarchical mode reproduces every DECISION
    (route/r/p/v) and the per-task accuracy/energy exactly, keeps delay and
    cost finite (queueing reflects the partitioned pools), bounds the
    in-loop collective footprint at O(n_devices) scalars, and the static
    divisibility guard fires."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.serving.policy import make_policy
        from repro.serving.session import ServeSession, _serve_run_sharded
        from repro.serving.simulator import SimConfig, Simulator
        from repro.sharding.audit import max_loop_collective_elems

        sys_ = SystemConfig()
        m, r = 64, 4
        simc = SimConfig(n_tasks=m, n_rounds=r, seed=7, bw_fluctuation=0.2)
        stream = Simulator(sys_, simc).sample_stream(r)
        stream = dataclasses.replace(
            stream, bw_scale=jnp.full((r,), 0.5, jnp.float32))
        pol = make_policy("r2evid", sys_)
        kw = dict(sim=simc, n_edge=16, n_cloud=8)
        dense = ServeSession(pol, m, **kw).run(stream)
        mesh = jax.make_mesh((8,), ("data",))
        gath = ServeSession(pol, m, **kw).run_sharded(mesh, stream)
        hier = ServeSession(pol, m, **kw).run_sharded(
            mesh, stream, hierarchical=True)
        for k in dense:
            np.testing.assert_allclose(
                np.asarray(dense[k]), np.asarray(gath[k]),
                atol=1e-5, rtol=1e-5, err_msg="gathered " + k)
        for k in ("route", "r", "p", "v"):
            np.testing.assert_array_equal(
                np.asarray(dense[k]), np.asarray(hier[k]),
                err_msg="hier " + k)
        for k in ("accuracy", "energy"):
            np.testing.assert_allclose(
                np.asarray(dense[k]), np.asarray(hier[k]),
                atol=1e-5, rtol=1e-5, err_msg="hier " + k)
        for k in ("delay", "cost"):
            v = np.asarray(hier[k])
            assert np.isfinite(v).all(), k
        assert (np.asarray(hier["delay"]) > 0).all()

        state = pol.init(m)
        foot = lambda h: max_loop_collective_elems(
            lambda st, obs: _serve_run_sharded(
                pol, st, obs, 16, 8, mesh, "data", stream.dx is not None,
                None, None, None, h),
            state, stream)
        h, g = foot(True), foot(False)
        assert h <= 4, ("hierarchical round body moved", h, "elems")
        assert g >= m // 8, g

        try:
            ServeSession(pol, m, sim=simc, n_edge=16, n_cloud=9).run_sharded(
                mesh, stream, hierarchical=True)
        except ValueError as e:
            assert "divide" in str(e), e
        else:
            raise AssertionError("indivisible pool accepted")
        print("OK")
        """)


def test_uneven_m_churn_outage_collapse_parity():
    """4 fake devices, M=13 (pads to 16), slot-pool churn composed with the
    outage_collapse scenario: the gathered mode reproduces dense on every
    key; the hierarchical mode keeps the admission arithmetic and every
    decision identical (alive/route/r/p/v exact, accuracy close)."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.serving.policy import make_policy
        from repro.serving.scenarios import apply_scenario, compile_scenario
        from repro.serving.session import AdmissionConfig, ServeSession
        from repro.serving.simulator import SimConfig, Simulator

        sys_ = SystemConfig()
        m, r = 13, 8
        simc = SimConfig(n_tasks=m, n_rounds=r, seed=11, bw_fluctuation=0.2,
                         n_edge_servers=8, n_cloud_servers=4)
        stream = Simulator(sys_, simc).sample_stream(r)
        rng = np.random.default_rng(0)
        stream = dataclasses.replace(
            stream,
            arrive_n=jnp.asarray(rng.poisson(2.0, size=r), jnp.int32),
            depart=jnp.asarray(rng.random((r, m)) < 0.15))
        trace = compile_scenario("outage_collapse", sys_, simc, r, seed=0)
        stream = apply_scenario(stream, trace)

        pol = make_policy("r2evid", sys_)
        acfg = AdmissionConfig(init_alive=m // 2)
        dense = ServeSession(pol, m, sim=simc, admission=acfg).run(stream)
        mesh = jax.make_mesh((4,), ("data",))
        gath = ServeSession(pol, m, sim=simc,
                            admission=acfg).run_sharded(mesh, stream)
        hier = ServeSession(pol, m, sim=simc, admission=acfg).run_sharded(
            mesh, stream, hierarchical=True)
        assert set(dense) == set(gath) == set(hier)
        for k in dense:
            np.testing.assert_allclose(
                np.asarray(dense[k]), np.asarray(gath[k]),
                atol=1e-5, rtol=1e-5, err_msg="gathered " + k)
        for k in ("alive", "route", "r", "p", "v",
                  "queue_depth", "admitted", "dropped"):
            np.testing.assert_array_equal(
                np.asarray(dense[k]), np.asarray(hier[k]),
                err_msg="hier " + k)
        np.testing.assert_allclose(
            np.asarray(dense["accuracy"]), np.asarray(hier["accuracy"]),
            atol=1e-5, rtol=1e-5, err_msg="hier accuracy")
        alive = np.asarray(hier["alive"])
        for k in ("cost", "delay", "energy", "accuracy"):
            v = np.asarray(hier[k])
            assert (v[~alive] == 0.0).all() and np.isfinite(v).all(), k
        print("OK")
        """)


def test_sniper_sharded_replicated_profile_parity():
    """4 fake devices: sniper's profile table is kept replicated and
    preseeded once from the gathered round-0 batch — the gathered run
    matches dense bit for bit (decisions) and the hierarchical run keeps
    decisions + accuracy identical (only queueing reflects the
    partitioned pools)."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.cost_model import SystemConfig
        from repro.serving.policy import make_policy
        from repro.serving.session import ServeSession
        from repro.serving.simulator import SimConfig, Simulator

        sys_ = SystemConfig()
        m, r = 12, 6
        simc = SimConfig(n_tasks=m, n_rounds=r, seed=2, bw_fluctuation=0.15,
                         n_edge_servers=8, n_cloud_servers=4)
        stream = Simulator(sys_, simc).sample_stream(r)
        pol = make_policy("sniper", sys_)
        dense = ServeSession(pol, m, sim=simc).run(stream)
        mesh = jax.make_mesh((4,), ("data",))
        gath = ServeSession(pol, m, sim=simc).run_sharded(mesh, stream)
        hier = ServeSession(pol, m, sim=simc).run_sharded(
            mesh, stream, hierarchical=True)
        for k in ("route", "r", "p", "v"):
            np.testing.assert_array_equal(
                np.asarray(dense[k]), np.asarray(gath[k]),
                err_msg="gathered " + k)
            np.testing.assert_array_equal(
                np.asarray(dense[k]), np.asarray(hier[k]),
                err_msg="hier " + k)
        for k in dense:
            np.testing.assert_allclose(
                np.asarray(dense[k]), np.asarray(gath[k]),
                atol=1e-6, rtol=1e-6, err_msg="gathered " + k)
        np.testing.assert_allclose(
            np.asarray(dense["accuracy"]), np.asarray(hier["accuracy"]),
            atol=1e-6, rtol=1e-6, err_msg="hier accuracy")
        print("OK")
        """)
