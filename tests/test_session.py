"""ServeSession tests: the unified compiled driver vs the pre-PR-5 goldens
(bit-level shim parity), step-vs-scan identity, the sharded run, the online
gate fine-tune carry, and the deprecation shims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig
from repro.core.features import feature_dim
from repro.core.gating import GateConfig, gate_specs
from repro.core.robust import RobustProblem
from repro.core.router import RouterEngine, init_router_state, route_scan
from repro.models.params import init_params
from repro.serving.policy import Observation, R2EVidPolicy, make_policy
from repro.serving.scan import serve_scan
from repro.serving.session import FinetuneConfig, ServeSession
from repro.serving.simulator import SimConfig, Simulator

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)
GCFG = GateConfig(d_feature=feature_dim())
GPARAMS = init_params(gate_specs(GCFG), jax.random.PRNGKey(0))


def _golden_inputs(m=12, r=6, seed=2026):
    rng = np.random.default_rng(seed)
    dx = jnp.asarray(rng.normal(size=(r, m, feature_dim())), jnp.float32)
    z = jnp.asarray(rng.uniform(0, 1, (r, m)), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.55, 0.82, (r, m)), jnp.float32)
    bwm = jnp.asarray(rng.uniform(0.8, 1.0, (r, 2)), jnp.float32)
    u = jnp.asarray(rng.uniform(0, 0.3, (r, 5)), jnp.float32)
    return dx, z, aq, bwm, u


# captured from the pre-PR-5 serve_scan (PR 4 code) on _golden_inputs():
# the session-based shim must reproduce these decisions exactly and the
# metric row-sums to float32 fidelity
GOLD_ROUTE = [[0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0],
              [0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0],
              [0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0],
              [0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0],
              [0, 0, 0, 1, 0, 1, 0, 0, 1, 1, 0, 0],
              [0, 0, 0, 1, 0, 1, 0, 0, 1, 1, 0, 0]]
GOLD_R = [[4, 4, 3, 3, 3, 3, 3, 3, 4, 2, 3, 4],
          [4, 3, 4, 2, 4, 3, 1, 4, 4, 4, 2, 4],
          [4, 3, 3, 4, 4, 4, 2, 3, 3, 3, 4, 4],
          [4, 4, 4, 3, 3, 4, 2, 1, 3, 1, 3, 4],
          [3, 4, 3, 4, 4, 4, 3, 4, 4, 4, 4, 1],
          [1, 4, 4, 4, 3, 4, 4, 4, 3, 3, 3, 2]]
GOLD_V = [[4, 4, 3, 3, 2, 4, 3, 3, 4, 4, 3, 2],
          [4, 4, 4, 4, 4, 4, 4, 4, 4, 2, 3, 1],
          [4, 4, 4, 4, 4, 4, 4, 2, 4, 2, 4, 4],
          [4, 4, 4, 4, 2, 4, 3, 4, 4, 4, 4, 4],
          [4, 3, 4, 2, 4, 4, 3, 4, 4, 4, 1, 3],
          [4, 4, 4, 4, 2, 4, 4, 4, 4, 3, 4, 4]]
GOLD_ROWSUMS = {
    "delay": [16.81609064, 20.77180046, 25.00040352, 20.27447271,
              20.64970917, 18.05102819],
    "energy": [217.6555326, 239.3669922, 247.2571917, 193.3907303,
               445.6462599, 248.4985284],
    "cost": [29.87542218, 35.1338203, 39.83583307, 31.8779161,
             47.38848132, 32.96093881],
    "accuracy": [8.253199637, 8.239819884, 8.602456927, 8.337873042,
                 8.376935661, 8.456099868],
    "tau": [5.942279458, 5.542289734, 5.938607693, 6.431960434,
            5.703292131, 5.632625118],
}
GOLD_FINAL_GATE_H_SUM = 1.8573305341415107
GOLD_FINAL_PREV_ROUTE = [0, 0, 0, 1, 0, 1, 0, 0, 1, 1, 0, 0]


def _check_golden(st, mets):
    np.testing.assert_array_equal(np.asarray(mets["route"]), GOLD_ROUTE)
    np.testing.assert_array_equal(np.asarray(mets["r"]), GOLD_R)
    np.testing.assert_array_equal(np.asarray(mets["v"]), GOLD_V)
    for k, want in GOLD_ROWSUMS.items():
        got = np.asarray(mets[k], np.float64).sum(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-6, err_msg=k)
    if st is not None:
        np.testing.assert_array_equal(np.asarray(st.prev_route),
                                      GOLD_FINAL_PREV_ROUTE)
        np.testing.assert_allclose(
            np.asarray(st.gate.h, np.float64).sum(), GOLD_FINAL_GATE_H_SUM,
            rtol=1e-6)


def test_serve_scan_shim_matches_pr4_golden():
    """The deprecation shim (old signature, session underneath) reproduces
    the PR 4 decisions bit-for-bit and the metrics to float32 fidelity."""
    dx, z, aq, bwm, u = _golden_inputs()
    st, mets = serve_scan(PROB, GCFG, GPARAMS, init_router_state(GCFG, 12),
                          dx, z, aq, bwm, u)
    _check_golden(st, mets)


def test_session_run_matches_pr4_golden_directly():
    """The new-API spelling (policy + session, no shim) hits the same golden."""
    dx, z, aq, bwm, u = _golden_inputs()
    policy = R2EVidPolicy(prob=PROB, gate_params=GPARAMS, gate_cfg=GCFG)
    session = ServeSession(policy, n_streams=12)
    mets = session.run(Observation(z=z, aq=aq, dx=dx, bw_mult=bwm, u=u))
    _check_golden(session.state, mets)


def test_session_step_sequence_matches_run_scan():
    """R ``session.step`` calls == one ``session.run`` scan (carry threading
    and the fused realization agree round for round)."""
    dx, z, aq, bwm, u = _golden_inputs(m=7, r=4)
    policy = R2EVidPolicy(prob=PROB, gate_params=GPARAMS, gate_cfg=GCFG)
    s_run = ServeSession(policy, n_streams=7)
    mets = s_run.run(Observation(z=z, aq=aq, dx=dx, bw_mult=bwm, u=u))
    s_step = ServeSession(policy, n_streams=7)
    for i in range(4):
        out = s_step.step(Observation(z=z[i], aq=aq[i], dx=dx[i],
                                      bw_mult=bwm[i], u=u[i]))
        for k in mets:
            np.testing.assert_allclose(np.asarray(mets[k][i]),
                                       np.asarray(out[k]), atol=1e-6,
                                       err_msg=f"round {i} {k}")
    np.testing.assert_array_equal(np.asarray(s_run.state.prev_route),
                                  np.asarray(s_step.state.prev_route))


@pytest.mark.parametrize("name", ["r2evid", "a2_cloud_only", "jcab", "rdap",
                                  "sniper"])
def test_session_run_sharded_matches_dense(name):
    """On the host mesh the sharded driver agrees with the dense scan for
    every shardable policy (the real multi-shard + padding path is covered
    by tests/test_engine_scan.py's multi-device subprocess through the
    serve_scan shim)."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    scfg = SimConfig(n_rounds=4, n_tasks=6, seed=9, bw_fluctuation=0.1)
    sim = Simulator(SYS, scfg)
    stream = sim.sample_stream(feature_seed=1)
    if name == "r2evid":
        policy = make_policy(name, SYS, gate_cfg=GCFG, gate_params=GPARAMS)
    else:
        policy = make_policy(name, SYS)
    met_a = ServeSession(policy, n_streams=6).run(stream)
    sess_b = ServeSession(policy, n_streams=6)
    met_b = sess_b.run_sharded(mesh, stream)
    assert set(met_a) == set(met_b)
    for k in met_a:
        np.testing.assert_allclose(np.asarray(met_a[k]), np.asarray(met_b[k]),
                                   atol=1e-5, err_msg=k)


def test_session_sharded_rejects_opted_out_sniper():
    """Sniper runs sharded by default via its replicated profile table;
    ``replicated_profile=False`` restores the historical global coupling,
    and the session must refuse to shard THAT rather than silently change
    its decisions."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    sim = Simulator(SYS, SimConfig(n_rounds=2, n_tasks=6, seed=1))
    stream = sim.sample_stream()
    policy = dataclasses.replace(make_policy("sniper", SYS),
                                 replicated_profile=False)
    session = ServeSession(policy, n_streams=6)
    with pytest.raises(ValueError, match="shard"):
        session.run_sharded(mesh, stream)


# ---------------------------------------------------------------------------
# Online gate fine-tuning carry
# ---------------------------------------------------------------------------
def test_finetune_none_is_bit_identical():
    """``finetune=None`` (the default) lowers exactly today's path."""
    dx, z, aq, bwm, u = _golden_inputs()
    policy = R2EVidPolicy(prob=PROB, gate_params=GPARAMS, gate_cfg=GCFG)
    stream = Observation(z=z, aq=aq, dx=dx, bw_mult=bwm, u=u)
    met_a = ServeSession(policy, n_streams=12).run(stream)
    met_b = ServeSession(policy, n_streams=12, finetune=None).run(stream)
    for k in met_a:
        np.testing.assert_array_equal(np.asarray(met_a[k]),
                                      np.asarray(met_b[k]), err_msg=k)
    _check_golden(None, met_b)


def test_finetune_updates_gate_params_on_cadence():
    """With a FinetuneConfig the gate parameters move (every resync_period
    rounds), rounds before the first update are untouched, the run stays
    finite, and the caller's policy object keeps its original buffers."""
    dx, z, aq, bwm, u = _golden_inputs()
    policy = R2EVidPolicy(prob=PROB, gate_params=GPARAMS, gate_cfg=GCFG)
    stream = Observation(z=z, aq=aq, dx=dx, bw_mult=bwm, u=u)
    met_plain = ServeSession(policy, n_streams=12).run(stream)
    session = ServeSession(policy, n_streams=12,
                           finetune=FinetuneConfig(lr=1e-2, resync_period=2))
    met_ft = session.run(stream)
    assert np.isfinite(np.asarray(met_ft["cost"])).all()
    # first update applies after round 2 — rounds 0-1 identical to plain
    for k in met_plain:
        np.testing.assert_array_equal(np.asarray(met_ft[k][:2]),
                                      np.asarray(met_plain[k][:2]), err_msg=k)
    before = jax.tree_util.tree_leaves(policy.gate_params)
    after = jax.tree_util.tree_leaves(session.gate_params)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before, after)), "no parameter moved"
    # the donated carry must not have consumed the caller's params
    for a, b in zip(before, jax.tree_util.tree_leaves(GPARAMS)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a second run continues the round counter without recompiling state
    met_ft2 = session.run(stream)
    assert np.isfinite(np.asarray(met_ft2["cost"])).all()
    assert int(session._rounds_done) == 12


def test_finetune_requires_gate_mode():
    with pytest.raises(ValueError, match="gate"):
        ServeSession(make_policy("jcab", SYS), n_streams=4,
                     finetune=FinetuneConfig())


# ---------------------------------------------------------------------------
# RouterEngine deprecation shim
# ---------------------------------------------------------------------------
def test_router_engine_shim_matches_route_scan():
    """engine.step_many (session underneath) == the raw route_scan driver,
    bit for bit, including the threaded carry."""
    m, s = 6, 5
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, m), jnp.float32)
    dx_seq = jnp.asarray(rng.normal(size=(s, m, feature_dim())), jnp.float32)
    st, sols_raw = route_scan(PROB, GCFG, GPARAMS, init_router_state(GCFG, m),
                              dx_seq, z, aq)
    engine = RouterEngine(PROB, GCFG, GPARAMS, n_streams=m)
    sols = engine.step_many(dx_seq, z, aq)
    for k in ("route", "r", "p", "v"):
        np.testing.assert_array_equal(np.asarray(sols[k]),
                                      np.asarray(sols_raw[k]), err_msg=k)
    np.testing.assert_allclose(np.asarray(sols["tau"]),
                               np.asarray(sols_raw["tau"]), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(engine.state.prev_route),
                                  np.asarray(st.prev_route))


def test_simulator_run_rejects_host_closures():
    """The method(rnd, state) plumbing is gone — a clear error points at
    make_policy instead of silently doing something different."""
    from repro.serving.baselines import make_method

    sim = Simulator(SYS, SimConfig(n_rounds=2, n_tasks=4))
    with pytest.raises(TypeError, match="make_policy"):
        sim.run(make_method("JCAB", SYS))
