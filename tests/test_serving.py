"""Serving simulator + baselines + paper-claim bands + straggler hedging.

The paper-claim bands run every method — baselines and R2E-VID alike —
through the same compiled ``ServeSession.run`` scan (``Simulator.run``
drives :mod:`repro.serving.policy` policies; the old host closures survive
only as parity oracles, covered by tests/test_policy.py)."""
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig, accuracy_table
from repro.runtime.straggler import (hedged_dispatch, hedged_dispatch_jnp,
                                     p99, p99_jnp)
from repro.serving.policy import make_policy
from repro.serving.simulator import SimConfig, Simulator

SYS = SystemConfig()


def _run(name, *, req="stable", fluct=0.1, seed=42, **kw):
    sim = Simulator(SYS, SimConfig(n_rounds=6, n_tasks=50, requirement=req,
                                   bw_fluctuation=fluct, seed=seed))
    policy = make_policy(name, SYS, **kw)
    sim.rng = np.random.default_rng(seed)
    return sim.run(policy)


def test_r2evid_success_band():
    res = _run("R2E-VID", req="stable")
    assert res["success"] >= 0.9, res


def test_r2evid_beats_cloud_only_on_cost():
    ours = _run("R2E-VID", req="fluctuating", fluct=0.25)
    a2 = _run("A2", req="fluctuating", fluct=0.25)
    reduction = 1 - ours["cost"] / a2["cost"]
    assert reduction > 0.3, f"cost reduction {reduction:.2%} below paper band"


def test_r2evid_beats_nominal_methods_on_success():
    ours = _run("R2E-VID", req="fluctuating", fluct=0.2)
    for base in ("RDAP", "Sniper"):
        b = _run(base, req="fluctuating", fluct=0.2)
        assert ours["success"] > b["success"], (base, ours["success"], b["success"])


def _run_ablation(**kw):
    sim = Simulator(SYS, SimConfig(n_rounds=6, n_tasks=50, requirement="fluctuating",
                                   bw_fluctuation=0.15, seed=42))
    policy = make_policy("R2E-VID", SYS, **kw)
    sim.rng = np.random.default_rng(42)
    return sim.run(policy)


def test_ablation_directions():
    full = _run_ablation()
    no_s1 = _run_ablation(use_stage1=False)
    no_s2 = _run_ablation(use_stage2=False)
    # removing stage 1 hurts accuracy/success; removing stage 2 hurts cost
    assert no_s1["accuracy"] < full["accuracy"]
    assert no_s2["cost"] > full["cost"]


def test_simulator_reproducible():
    r1 = _run("JCAB", seed=7)
    r2 = _run("JCAB", seed=7)
    assert r1 == r2


def test_accuracy_table_monotonicity():
    """More resolution / bigger version / cloud tier never hurts accuracy."""
    import jax.numpy as jnp
    f = np.asarray(accuracy_table(SYS, jnp.asarray([0.5])))[0]  # (N, Z, K, 2)
    assert np.all(np.diff(f, axis=0) >= -1e-6)   # resolution
    assert np.all(np.diff(f, axis=2) >= -1e-6)   # version
    assert np.all(f[..., 1] >= f[..., 0] - 1e-6)  # cloud >= edge


def test_bandwidth_repair_meets_budget():
    import jax.numpy as jnp
    from repro.core.robust import RobustProblem, solve_ccg
    from repro.core.router import enforce_bandwidth
    from repro.core.cost_model import cost_tables

    prob = RobustProblem.build(SYS)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.uniform(0, 1, 80), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, 80), jnp.float32)
    sol = solve_ccg(prob, z, aq)
    fixed, bw_hist = enforce_bandwidth(SYS, sol, z, aq, total_budget=200.0, rounds=60)
    _, _, bw_tab = cost_tables(SYS)
    final_bw = float(np.asarray(bw_tab)[np.asarray(fixed["r"]), np.asarray(fixed["p"]),
                                        np.asarray(fixed["route"])].sum())
    start_bw = float(bw_hist[0])
    # repair monotonically reduces bandwidth draw and never violates accuracy
    assert final_bw <= start_bw + 1e-6
    hist = np.asarray(bw_hist)
    assert np.all(np.diff(hist) <= 1e-6)
    f = np.asarray(accuracy_table(SYS, z))
    idx = np.arange(len(np.asarray(fixed["r"])))
    acc = f[idx, np.asarray(fixed["r"]), np.asarray(fixed["p"]),
            np.asarray(fixed["v"]), np.asarray(fixed["route"])]
    infeasible = np.asarray(sol["infeasible"])
    assert np.all(acc[~infeasible] >= np.asarray(aq)[~infeasible] - 1e-6)


def test_hedged_dispatch_cuts_tail():
    rng = np.random.default_rng(0)
    base = rng.exponential(1.0, (4000, 2))
    base[::50, 0] += 20.0  # stragglers on the primary
    plain = base[:, 0]
    hedged = hedged_dispatch(base, hedge_quantile=0.9)
    assert p99(hedged) < 0.7 * p99(plain)
    # hedging never makes the median worse
    assert np.median(hedged) <= np.median(plain) + 1e-9

    # the jnp port (the form realize_rounds fuses into the serve scan) must
    # match the numpy oracle to float32 fidelity, and its p99 companion must
    # report the same tail
    hedged_j = np.asarray(hedged_dispatch_jnp(base, hedge_quantile=0.9))
    np.testing.assert_allclose(hedged_j, hedged, rtol=1e-5, atol=1e-4)
    assert float(p99_jnp(hedged_j)) < 0.7 * p99(plain)
    np.testing.assert_allclose(float(p99_jnp(hedged)), p99(hedged),
                               rtol=1e-3)

    # single-replica pools degrade to the primary draws on both paths
    np.testing.assert_array_equal(hedged_dispatch(base[:, :1]), plain)
    np.testing.assert_allclose(
        np.asarray(hedged_dispatch_jnp(base[:, :1])),
        plain.astype(np.float32), rtol=1e-6)

    # the jnp port is shape-generic: a batched (R, M, 2) call hedges each
    # round against its own deadline, matching the per-round oracle
    batched = base.reshape(4, 1000, 2)
    out_b = np.asarray(hedged_dispatch_jnp(batched, hedge_quantile=0.9))
    for i in range(4):
        np.testing.assert_allclose(
            out_b[i], hedged_dispatch(batched[i], hedge_quantile=0.9),
            rtol=1e-5, atol=1e-4)
