"""Router-layer tests: decision lattice, C6 bandwidth repair, the temporal-
consistency constraint, the streaming engine, and vectorized realization."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables
from repro.core.features import feature_dim
from repro.core.gating import GateConfig, gate_specs
from repro.core.lattice import DecisionLattice, version_deviations
from repro.core.robust import RobustProblem, exact_oracle, solve_ccg
from repro.core.router import (
    RouterConfig,
    RouterEngine,
    apply_temporal_consistency,
    enforce_bandwidth,
    init_router_state,
    route_step,
    stage1_configure,
)
from repro.models.params import init_params

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)
LAT = PROB.lat


# ---------------------------------------------------------------------------
# DecisionLattice
# ---------------------------------------------------------------------------
def test_lattice_index_roundtrip():
    """flatten∘unflatten = id over the full F index space, and back."""
    ys = jnp.arange(LAT.n_flat)
    route, r, p = LAT.unflatten_index(ys)
    assert np.all(np.asarray(LAT.flatten_index(route, r, p)) == np.asarray(ys))
    # all (route, r, p) triples map to distinct flat indices in range
    rt, rr, pp = np.meshgrid(np.arange(2), np.arange(SYS.n_res), np.arange(SYS.n_fps),
                             indexing="ij")
    flat = np.asarray(LAT.flatten_index(rt.ravel(), rr.ravel(), pp.ravel()))
    assert sorted(flat.tolist()) == list(range(LAT.n_flat))


def test_lattice_flat_tables_match_natural_layout():
    c1, b2, bw = cost_tables(SYS)
    ys = jnp.arange(LAT.n_flat)
    route, r, p = LAT.unflatten_index(ys)
    np.testing.assert_allclose(np.asarray(LAT.c1_flat), np.asarray(c1)[r, p, route])
    np.testing.assert_allclose(np.asarray(LAT.b2_flat), np.asarray(b2)[r, p, :, route])
    np.testing.assert_allclose(np.asarray(LAT.bw_flat), np.asarray(bw)[r, p, route])


def test_lattice_accuracy_flat_matches_table():
    z = jnp.asarray([0.1, 0.6, 0.95], jnp.float32)
    f = np.asarray(accuracy_table(SYS, z))
    f_flat = np.asarray(LAT.accuracy_flat(z))
    ys = np.arange(LAT.n_flat)
    route, r, p = LAT.unflatten_index(ys)
    np.testing.assert_allclose(f_flat, f[:, r, p, :, route].transpose(1, 0, 2))


def test_lattice_build_is_cached():
    assert DecisionLattice.build(SYS) is DecisionLattice.build(SystemConfig())


def test_version_deviations_monotone():
    u = np.asarray(version_deviations(SYS))
    assert u.shape == (SYS.num_versions,)
    assert np.all(np.diff(u) > 0)  # bigger models deviate more
    assert np.isclose(u[-1], SYS.u_dev)


# ---------------------------------------------------------------------------
# Solver parity on a fixed seed (pre-refactor golden decisions)
# ---------------------------------------------------------------------------
def test_solver_parity_fixed_seed_golden():
    """Refactored solve_ccg reproduces the pre-lattice solver's decisions and
    matches exact_oracle objectives on a fixed seed."""
    rng = np.random.default_rng(1234)
    z = jnp.asarray(rng.uniform(0, 1, 12), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, 12), jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    # golden decisions captured from the pre-refactor solver on this seed
    assert np.asarray(sol["route"]).tolist() == [0] * 12
    assert np.asarray(sol["r"]).tolist() == [4, 4, 4, 2, 3, 1, 1, 4, 3, 3, 3, 3]
    assert np.asarray(sol["p"]).tolist() == [3, 1, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0]
    assert np.asarray(sol["v"]).tolist() == [4, 4, 4, 4, 4, 3, 3, 4, 2, 4, 4, 4]
    np.testing.assert_allclose(
        np.asarray(sol["o_up"]),
        [2.2345657348632812, 1.1172828674316406, 1.1172828674316406,
         0.24828505516052246, 0.3879454433917999, 0.07857239246368408,
         0.07857239246368408, 1.1172828674316406, 0.13119734823703766,
         0.3879454433917999, 0.3879454433917999, 0.3879454433917999],
        rtol=1e-6,
    )
    y, obj = exact_oracle(PROB, z, aq)
    np.testing.assert_allclose(np.asarray(sol["o_up"]), np.asarray(obj), rtol=1e-6)
    y_sol = np.asarray(LAT.flatten_index(sol["route"], sol["r"], sol["p"]))
    assert np.all(y_sol == np.asarray(y))


# ---------------------------------------------------------------------------
# C6 bandwidth repair
# ---------------------------------------------------------------------------
def _inflated_solution(m=8, seed=0):
    """A deliberately over-provisioned solution (max fidelity, biggest model):
    lots of accuracy slack, so demotions are possible.  A CCG solution is
    already cost-minimal — i.e. at the feasibility frontier — so repair is a
    no-op on it; the repair mechanism only bites on slack-carrying configs."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.uniform(0.1, 0.6, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.6, m), jnp.float32)
    sol = {
        "route": jnp.zeros((m,), jnp.int32),
        "r": jnp.full((m,), SYS.n_res - 1, jnp.int32),
        "p": jnp.full((m,), SYS.n_fps - 1, jnp.int32),
        "v": jnp.full((m,), SYS.num_versions - 1, jnp.int32),
    }
    return z, aq, sol


def test_enforce_bandwidth_meets_budget_when_feasible():
    z, aq, sol = _inflated_solution()
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    budget = 0.5 * start_bw
    fixed, bw_hist = enforce_bandwidth(SYS, sol, z, aq, total_budget=budget, rounds=64)
    final_bw = float(np.asarray(LAT.solution_bandwidth(fixed)).sum())
    assert final_bw <= budget + 1e-6, (final_bw, budget)
    # the draw shrinks monotonically round over round
    assert np.all(np.diff(np.asarray(bw_hist)) <= 1e-6)


def test_enforce_bandwidth_demoted_tasks_stay_feasible():
    z, aq, sol = _inflated_solution(seed=3)
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    fixed, _ = enforce_bandwidth(SYS, sol, z, aq, total_budget=0.5 * start_bw, rounds=64)
    f = np.asarray(accuracy_table(SYS, z))
    idx = np.arange(len(np.asarray(fixed["r"])))
    acc = f[idx, np.asarray(fixed["r"]), np.asarray(fixed["p"]),
            np.asarray(fixed["v"]), np.asarray(fixed["route"])]
    margin = SYS.acc_margin_robust
    assert np.all(acc >= np.asarray(aq) + margin - 1e-6)


def test_enforce_bandwidth_noop_when_under_budget():
    z, aq, sol = _inflated_solution(seed=1)
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    fixed, _ = enforce_bandwidth(SYS, sol, z, aq, total_budget=2.0 * start_bw, rounds=16)
    assert np.all(np.asarray(fixed["r"]) == np.asarray(sol["r"]))
    assert np.all(np.asarray(fixed["p"]) == np.asarray(sol["p"]))


def test_enforce_bandwidth_noop_on_ccg_solution():
    """CCG solutions are cost-minimal, hence at the feasibility frontier: no
    single demotion stays feasible, so repair cannot (and must not) move them."""
    rng = np.random.default_rng(0)
    m = 20
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, m), jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    fixed, _ = enforce_bandwidth(SYS, sol, z, aq, total_budget=0.5 * start_bw, rounds=32)
    final_bw = float(np.asarray(LAT.solution_bandwidth(fixed)).sum())
    assert final_bw <= start_bw + 1e-6


# ---------------------------------------------------------------------------
# Temporal-consistency constraint
# ---------------------------------------------------------------------------
def test_temporal_consistency_suppresses_and_allows_flips():
    rcfg = RouterConfig(delta0=0.0, delta1=4.0)
    prev_route = jnp.asarray([0, 0, 1, -1], jnp.int32)
    prev_tau = jnp.asarray([0.5, 0.5, 0.5, 0.5], jnp.float32)
    #            small Δτ   large Δτ   small Δτ   no history
    taus = jnp.asarray([0.6, 0.9, 0.55, 0.6], jnp.float32)
    want = jnp.asarray([1, 1, 0, 1], jnp.int32)  # desired routes (all flips)
    out = np.asarray(apply_temporal_consistency(want, prev_route, taus, prev_tau, rcfg))
    # |Δτ|·δ1 = 0.4 < 1 -> flip suppressed; 1.6 >= 1 -> allowed; first segment free
    assert out.tolist() == [0, 1, 1, 1]


def test_stage1_first_segment_ignores_history():
    m = 3
    taus = jnp.asarray([0.9, 0.9, 0.1], jnp.float32)
    z = jnp.asarray([0.3, 0.3, 0.3], jnp.float32)
    # A^q low enough that the smallest edge model is Stage-1 feasible
    aq = jnp.asarray([0.5, 0.5, 0.5], jnp.float32)
    prev_route = -jnp.ones((m,), jnp.int32)
    prev_tau = jnp.zeros((m,), jnp.float32)
    route, r = stage1_configure(SYS, taus, z, aq, prev_route, prev_tau)
    # high tau -> cloud, low tau -> edge; no suppression without history
    assert np.asarray(route).tolist() == [1, 1, 0]


def test_stage1_flip_suppressed_with_history():
    m = 2
    taus = jnp.asarray([0.9, 0.9], jnp.float32)  # both want cloud
    z = jnp.asarray([0.3, 0.3], jnp.float32)
    aq = jnp.asarray([0.5, 0.5], jnp.float32)
    prev_route = jnp.asarray([0, 0], jnp.int32)
    # task 0: tau barely moved -> flip suppressed; task 1: big move -> allowed
    prev_tau = jnp.asarray([0.85, 0.3], jnp.float32)
    route, _ = stage1_configure(SYS, taus, z, aq, prev_route, prev_tau)
    assert np.asarray(route).tolist() == [0, 1]


# ---------------------------------------------------------------------------
# Streaming engine
# ---------------------------------------------------------------------------
def test_route_step_threads_state_and_matches_solver():
    m = 8
    rng = np.random.default_rng(5)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, m), jnp.float32)
    state = init_router_state(gcfg, m)
    assert np.all(np.asarray(state.prev_route) == -1)

    dx = jnp.asarray(rng.normal(size=(m, feature_dim())), jnp.float32)
    state, sol = route_step(PROB, gcfg, gparams, state, dx, z, aq)
    # state advanced: history recorded, gate recurrence progressed
    assert np.all(np.asarray(state.prev_route) == np.asarray(sol["route"]))
    np.testing.assert_allclose(np.asarray(state.prev_tau), np.asarray(sol["tau"]))
    assert np.all(np.asarray(state.gate.var_idx) == 1)
    for key in ("route", "r", "p", "v", "tau", "warm_route", "warm_r"):
        assert key in sol

    # a second step sees the first step's routes as history
    state2, sol2 = route_step(PROB, gcfg, gparams, state, dx * 0.9, z, aq)
    assert np.all(np.asarray(state2.gate.var_idx) == 2)
    allowed = np.abs(np.asarray(sol2["tau"]) - np.asarray(sol["tau"])) * 4.0 >= 1.0
    flipped = np.asarray(sol2["route"]) != np.asarray(sol["route"])
    assert not np.any(flipped & ~allowed), "forbidden route flip leaked through"


def test_router_engine_steady_state_routes_under_budget():
    m = 16
    rng = np.random.default_rng(11)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(1))
    engine = RouterEngine(PROB, gcfg, gparams, n_streams=m)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.7, m), jnp.float32)
    for _ in range(4):
        dx = jnp.asarray(rng.normal(size=(m, feature_dim())), jnp.float32)
        sol = engine.step(dx, z, aq)
    bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    assert bw <= SYS.total_bw_mbps + 1e-6
    engine.reset()
    assert np.all(np.asarray(engine.state.prev_route) == -1)


# ---------------------------------------------------------------------------
# Vectorized realization parity
# ---------------------------------------------------------------------------
def test_vectorized_realize_matches_loop_reference():
    from repro.serving.baselines import make_method
    from repro.serving.simulator import SimConfig, Simulator

    sim = Simulator(SYS, SimConfig(n_tasks=64, seed=9, bw_fluctuation=0.2,
                                   requirement="fluctuating"))
    method = make_method("JCAB", SYS)
    state = {}
    for _ in range(3):
        rnd = sim.sample_round()
        cfg = method(rnd, state)
        noise = np.zeros(64)
        met_v = sim._realize_deterministic(rnd, cfg)
        met_r = sim.realize_reference(rnd, cfg, noise=noise)
        for k in ("delay", "energy", "cost", "accuracy"):
            np.testing.assert_allclose(met_v[k], met_r[k], atol=1e-4, rtol=1e-4)


def test_realize_batch_matches_per_round_realize():
    from repro.serving.baselines import make_method
    from repro.serving.simulator import SimConfig, Simulator

    sim = Simulator(SYS, SimConfig(n_tasks=32, seed=2, bw_fluctuation=0.1))
    method = make_method("RDAP", SYS)
    state = {}
    rnds, cfgs, singles = [], [], []
    for _ in range(4):
        rnd = sim.sample_round()
        cfg = method(rnd, state)
        rnds.append(rnd)
        cfgs.append(cfg)
        singles.append(sim._realize_deterministic(rnd, cfg))
    batched = sim.realize_batch(rnds, cfgs)
    for k in ("delay", "energy", "cost"):
        got = batched[k]
        want = np.stack([s[k] for s in singles])
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Table-free hot path: bit-parity vs the table-based goldens
# ---------------------------------------------------------------------------
def test_stage1_accuracy_pointwise_matches_table_slice():
    """``accuracy_stage1`` == the f[:, :, -1, 0, 0] slice of the broadcast
    table, bitwise — Stage-1 decisions cannot drift off the table path."""
    from repro.core.cost_model import accuracy_stage1

    z = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 33), jnp.float32)
    table_slice = np.asarray(accuracy_table(SYS, z))[:, :, -1, 0, 0]
    pointwise = np.asarray(accuracy_stage1(SYS, z))
    np.testing.assert_array_equal(pointwise, table_slice)


def _enforce_bandwidth_table_golden(lat, sol, difficulty, acc_req,
                                    total_budget=None, rounds=8):
    """The pre-table-free C6 repair (builds the (M, N, Z, K, 2) accuracy
    table + fancy-index gathers) — kept verbatim as the parity golden."""
    from repro.core.robust import BIG

    sys = lat.sys
    bw_tab = lat.bw
    f = lat.accuracy(difficulty)
    budget = sys.total_bw_mbps if total_budget is None else total_budget
    margin = sys.acc_margin_robust
    m = sol["r"].shape[0]

    def round_fn(state, _):
        r, p = state
        bw = bw_tab[r, p, sol["route"]]
        excess = bw.sum() - budget
        p_dn = jnp.maximum(p - 1, 0)
        r_dn = jnp.maximum(r - 1, 0)
        f_pdn = f[jnp.arange(m), r, p_dn, sol["v"], sol["route"]]
        f_rdn = f[jnp.arange(m), r_dn, p, sol["v"], sol["route"]]
        can_p = (p > 0) & (f_pdn >= acc_req + margin)
        can_r = (r > 0) & (f_rdn >= acc_req + margin)
        gain_p = bw - bw_tab[r, p_dn, sol["route"]]
        gain_r = bw - bw_tab[r_dn, p, sol["route"]]
        gain = jnp.where(can_p, gain_p, jnp.where(can_r, gain_r, -BIG))
        order = jnp.argsort(-gain)
        gain_sorted = gain[order]
        cum_before = jnp.concatenate(
            [jnp.zeros((1,), gain.dtype), jnp.cumsum(gain_sorted)[:-1]])
        demote_sorted = (excess > 0) & (cum_before < excess) & (gain_sorted > 0)
        demote = jnp.zeros((m,), bool).at[order].set(demote_sorted)
        r = jnp.where(demote & ~can_p, r_dn, r)
        p = jnp.where(demote & can_p, p_dn, p)
        return (r, p), excess + budget

    (r, p), bw_hist = jax.lax.scan(
        round_fn, (sol["r"], sol["p"]), None, length=rounds)
    return dict(sol, r=r, p=p), bw_hist


def test_enforce_bandwidth_table_free_matches_table_golden():
    """Pointwise-accuracy + hoisted-panel C6 repair == the table-building
    golden, bit for bit (decisions AND the bandwidth history), across easy
    and tight budgets."""
    m = 41
    rng = np.random.default_rng(11)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    sol = {k: sol[k] for k in ("route", "r", "p", "v")}
    start_bw = float(np.asarray(LAT.solution_bandwidth(sol)).sum())
    for frac in (2.0, 0.6, 0.25):   # no-op, moderate, aggressive demotion
        budget = frac * start_bw
        got, got_hist = enforce_bandwidth(LAT, sol, z, aq, total_budget=budget)
        want, want_hist = _enforce_bandwidth_table_golden(
            LAT, sol, z, aq, total_budget=budget)
        for k in got:
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(want[k]),
                err_msg=f"frac={frac}:{k}")
        np.testing.assert_array_equal(
            np.asarray(got_hist), np.asarray(want_hist), err_msg=f"frac={frac}")


def test_route_windowed_jit_matches_eager_golden():
    """The jitted windowed ``route`` == the original eager composition
    (windowed gate scan -> table-based Stage-1 -> CCG -> temporal
    consistency -> table-based C6), decision-bitwise — with and without
    history."""
    from repro.core.gating import gate_scan_batch
    from repro.core.router import apply_temporal_consistency, route

    m, t = 9, 6
    rng = np.random.default_rng(5)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    dx_win = jnp.asarray(rng.normal(size=(m, t, feature_dim())), jnp.float32)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
    rcfg = RouterConfig()
    histories = [
        (None, None),
        (jnp.asarray(rng.integers(0, 2, m), jnp.int32),
         jnp.asarray(rng.uniform(0, 1, m), jnp.float32)),
    ]
    for prev_route, prev_tau in histories:
        got = route(PROB, gcfg, gparams, dx_win, z, aq,
                    prev_route=prev_route, prev_tau=prev_tau)

        pr = -jnp.ones((m,), jnp.int32) if prev_route is None else prev_route
        pt = jnp.zeros((m,)) if prev_tau is None else prev_tau
        taus_seq, _, _ = gate_scan_batch(gcfg, gparams, dx_win)
        taus = taus_seq[:, -1]
        # table-based Stage-1 (the pre-change implementation)
        f = LAT.accuracy(z)
        f_edge_v1 = f[:, :, -1, 0, 0]
        feasible_edge = f_edge_v1 >= aq[:, None]
        first_ok = jnp.argmax(feasible_edge, axis=1)
        any_ok = feasible_edge.any(axis=1)
        warm_r = jnp.where(any_ok, first_ok, SYS.n_res - 1)
        warm_route = jnp.where(
            any_ok, (taus > rcfg.tau_cloud).astype(jnp.int32), 1)
        warm_route = apply_temporal_consistency(warm_route, pr, taus, pt, rcfg)
        warm_y = LAT.flatten_index(warm_route, warm_r, SYS.n_fps - 1)
        sol = solve_ccg(PROB, z, aq, warm_y=warm_y.astype(jnp.int32))
        sol = dict(sol, route=apply_temporal_consistency(
            sol["route"], pr, taus, pt, rcfg))
        sol, _ = _enforce_bandwidth_table_golden(
            LAT, sol, z, aq, rounds=rcfg.repair_rounds)
        for k in ("route", "r", "p", "v", "iters", "infeasible"):
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(sol[k]), err_msg=k)
        np.testing.assert_allclose(np.asarray(got["tau"]), np.asarray(taus),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got["warm_route"]),
                                      np.asarray(warm_route))


def test_solve_ccg_finish_is_table_free_identical():
    """The table-free epilogue (bitmask feas + fused best-acc fallback)
    keeps v*/fallback decisions bit-identical to the while_loop oracle on a
    batch mixing converged, warm-started, and all-infeasible lanes."""
    from repro.core.robust import solve_ccg_while

    z = jnp.asarray([0.3, 0.95, 0.6, 0.1, 0.8], jnp.float32)
    aq = jnp.asarray([0.55, 0.99, 0.72, 0.5, 0.99], jnp.float32)  # 1, 4 inf.
    warm_y = jnp.asarray([-1, -1, 12, 0, 3], jnp.int32)
    sol_u = solve_ccg(PROB, z, aq, warm_y=warm_y)
    sol_w = solve_ccg_while(PROB, z, aq, warm_y=warm_y)
    for k in sol_u:
        np.testing.assert_array_equal(
            np.asarray(sol_u[k]), np.asarray(sol_w[k]), err_msg=k)
    assert np.asarray(sol_u["infeasible"]).tolist() == [
        False, True, False, False, True]
