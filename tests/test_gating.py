"""Temporal gating unit (Eq. 5-6) invariants + meta-training curriculum."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curriculum import CurriculumConfig, offline_warmup, online_finetune
from repro.core.features import feature_dim, motion_features, segment_features
from repro.core.gating import GateConfig, gate_loss, gate_scan, gate_specs, init_state
from repro.data.video import VideoConfig, generate_stream
from repro.models.params import init_params

GCFG = GateConfig(d_feature=8, d_hidden=16, var_window=4)


def _params(seed=0):
    return init_params(gate_specs(GCFG), jax.random.PRNGKey(seed))


def test_tau_in_unit_interval():
    p = _params()
    dxs = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    taus, gs, _ = gate_scan(GCFG, p, dxs)
    assert taus.shape == (32,)
    assert jnp.all((taus >= 0) & (taus <= 1))
    assert jnp.all((gs >= 0) & (gs <= 1))


def test_volatility_opens_gate():
    """Eq. 5: with alpha > 0, higher recent variance -> larger gate."""
    p = _params()
    p = dict(p, alpha=jnp.asarray(5.0))
    calm = jnp.zeros((16, 8))
    volatile = jax.random.normal(jax.random.PRNGKey(2), (16, 8)) * 2.0
    _, g_calm, _ = gate_scan(GCFG, p, calm)
    _, g_vol, _ = gate_scan(GCFG, p, volatile)
    assert float(g_vol[4:].mean()) > float(g_calm[4:].mean())


def test_state_streaming_consistency():
    """Scanning in two chunks with carried state == one scan."""
    p = _params()
    dxs = jax.random.normal(jax.random.PRNGKey(3), (20, 8))
    taus_full, _, _ = gate_scan(GCFG, p, dxs)
    t1, _, st = gate_scan(GCFG, p, dxs[:10])
    t2, _, _ = gate_scan(GCFG, p, dxs[10:], st)
    np.testing.assert_allclose(jnp.concatenate([t1, t2]), taus_full, atol=1e-6)


def test_offline_warmup_reduces_loss():
    rng = np.random.default_rng(0)

    def data():
        while True:
            dxs = rng.normal(0, 1, (8, 12, GCFG.d_feature)).astype(np.float32)
            # oracle: cloud benefit correlates with feature magnitude
            labels = (np.linalg.norm(dxs, axis=-1) > 3.2).astype(np.float32)
            yield jnp.asarray(dxs), jnp.asarray(labels)

    ccfg = CurriculumConfig(warmup_steps=60, lr=5e-2)
    params, losses = offline_warmup(GCFG, data(), ccfg, jax.random.PRNGKey(0))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "warm-up did not learn"


def test_online_proximal_stays_near_anchor():
    rng = np.random.default_rng(1)

    def data():
        while True:
            dxs = rng.normal(0, 1, (4, 8, GCFG.d_feature)).astype(np.float32)
            labels = np.ones((4, 8), np.float32)  # drifted objective
            yield jnp.asarray(dxs), jnp.asarray(labels)

    params = _params()
    ccfg = CurriculumConfig(online_steps=40, lr=5e-2, mu=10.0)
    tuned, _ = online_finetune(GCFG, params, data(), ccfg)
    drift = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(tuned), jax.tree_util.tree_leaves(params))
    )
    ccfg_free = CurriculumConfig(online_steps=40, lr=5e-2, mu=0.0)
    free, _ = online_finetune(GCFG, params, data(), ccfg_free)
    drift_free = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(free), jax.tree_util.tree_leaves(params))
    )
    assert drift < drift_free, "proximal term did not constrain drift"


def test_motion_features_shapes_and_ma():
    frames = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (17, 32, 32)), jnp.float32)
    dx = motion_features(frames)
    assert dx.shape == (16, feature_dim())
    seg = segment_features(frames, 4)
    assert seg.shape == (4, feature_dim())


def test_motion_features_track_motion_level():
    """Faster blob motion -> larger mean |diff| feature (the 'stats' block)."""
    vcfg = VideoConfig(height=48, width=48)
    slow, _ = generate_stream(vcfg, 4, motion_profile=np.full(4, 0.05),
                              rng=np.random.default_rng(0))
    fast, _ = generate_stream(vcfg, 4, motion_profile=np.full(4, 0.95),
                              rng=np.random.default_rng(0))
    f_slow = motion_features(jnp.asarray(slow))[:, -3]   # mean-diff stat
    f_fast = motion_features(jnp.asarray(fast))[:, -3]
    assert float(f_fast.mean()) > float(f_slow.mean())


def test_resync_cadence_one_matches_looped_oracle():
    """``resync_period=1`` recomputes the running Σ/Σ² from the exact ring
    buffer every step, so the batched incremental volatility is drift-free:
    the running sums equal a fresh buffer scan bitwise at every step, and the
    taus match the looped per-stream ``gate_step`` oracle."""
    from repro.core.gating import gate_step, gate_step_batch, init_batch_state

    cfg = GateConfig(d_feature=8, d_hidden=16, var_window=4, resync_period=1)
    p = init_params(gate_specs(cfg), jax.random.PRNGKey(4))
    steps, m = 9, 3
    dxs = jax.random.normal(jax.random.PRNGKey(5), (steps, m, cfg.d_feature))

    states = [init_state(cfg) for _ in range(m)]
    st = init_batch_state(cfg, m)
    for t in range(steps):
        st, (tau, _) = gate_step_batch(cfg, p, st, dxs[t])
        # every step: the incremental sums ARE the exact buffer reduction
        np.testing.assert_array_equal(
            np.asarray(st.var_sum), np.asarray(st.var_buf.sum(axis=1)))
        np.testing.assert_array_equal(
            np.asarray(st.var_sumsq),
            np.asarray(jnp.square(st.var_buf).sum(axis=1)))
        for i in range(m):
            states[i], (tau_ref, _) = gate_step(cfg, p, states[i], dxs[t, i])
            np.testing.assert_allclose(
                float(tau[i]), float(tau_ref), atol=1e-5)
