"""GPipe pipeline parallelism: multi-(fake-)device correctness + bubble math.

Runs in a subprocess (device count locks at first jax init).
"""
import os
import subprocess
import sys
import textwrap

from repro.sharding.pipeline import bubble_fraction

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.sharding.pipeline import pipeline, split_stages

    S, LPS, D, M, B = 4, 2, 16, 8, 4      # stages, layers/stage, width, microbatches, mb size
    L = S * LPS
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
    b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
    xs = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))

    def layer(wi, bi, x):
        return jnp.tanh(x @ wi + bi)

    # sequential reference
    ref = xs
    for i in range(L):
        ref = jax.vmap(lambda x: layer(w[i], b[i], x))(ref)

    def stage_fn(params, x):
        ws, bs = params
        def body(x, wb):
            return layer(wb[0], wb[1], x), None
        out, _ = jax.lax.scan(body, x, (ws, bs))
        return out

    mesh = jax.make_mesh((4,), ("stage",))
    stage_params = split_stages((w, b), S)
    fn = pipeline(stage_fn, mesh, axis="stage")
    out = jax.jit(fn)(stage_params, xs)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    print("OK", err)
    """
)


def test_pipeline_matches_sequential(tmp_path):
    script = tmp_path / "pp.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(script)], env=env, cwd=os.getcwd(),
        capture_output=True, text=True, timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2500:]
    assert "OK" in res.stdout


def test_bubble_fraction():
    assert bubble_fraction(8, 4) == 3 / 11
    assert bubble_fraction(1, 4) == 3 / 4   # single microbatch: mostly bubble
    assert bubble_fraction(64, 2) < 0.02    # deep microbatching amortizes
