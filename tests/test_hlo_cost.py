"""Trip-count-aware HLO analyzer: validate against programs with known FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    m, k, n = 128, 256, 64
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    acc = analyze(_compile_text(lambda x, y: x @ y, a, b))
    expected = 2 * m * k * n
    assert abs(acc["flops"] - expected) / expected < 0.01, acc["flops"]


def test_scan_multiplies_by_trip_count():
    """A scan of T matmuls must count T x the single-matmul FLOPs (this is
    exactly what XLA's own cost analysis gets wrong)."""
    m = 64
    a = jnp.zeros((m, m), jnp.float32)
    T = 17

    def fn(x):
        def body(c, _):
            return c @ a + c, None
        out, _ = jax.lax.scan(body, x, None, length=T)
        return out

    acc = analyze(_compile_text(fn, jnp.ones((m, m), jnp.float32)))
    expected = 2 * m * m * m * T
    assert abs(acc["flops"] - expected) / expected < 0.05, (acc["flops"], expected)


def test_nested_scan_trip_counts():
    m, t_outer, t_inner = 32, 5, 7
    a = jnp.zeros((m, m), jnp.float32)

    def fn(x):
        def inner(c, _):
            return c @ a, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=t_inner)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=t_outer)
        return out

    acc = analyze(_compile_text(fn, jnp.ones((m, m), jnp.float32)))
    expected = 2 * m ** 3 * t_outer * t_inner
    assert abs(acc["flops"] - expected) / expected < 0.05, (acc["flops"], expected)


def test_bytes_scale_with_scan_length():
    n = 4096

    def fn_t(T):
        def fn(x):
            def body(c, _):
                return c * 1.5 + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=T)
            return out
        return fn

    x = jnp.ones((n,), jnp.float32)
    b1 = analyze(_compile_text(fn_t(10), x))["bytes"]
    b2 = analyze(_compile_text(fn_t(40), x))["bytes"]
    ratio = b2 / max(b1, 1)
    assert 2.5 < ratio < 6.0, ratio  # ~4x more loop traffic


def test_bf16_adjustment_halves_f32():
    a = jnp.zeros((256, 256), jnp.float32)
    acc = analyze(_compile_text(lambda x: x + 1.0, a))
    assert acc["bytes_adj"] <= acc["bytes"] * 0.51
