"""Per-kernel validation: shape/dtype sweeps, interpret mode vs pure-jnp
oracle (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,d,win",
    [
        (2, 4, 2, 256, 64, None),
        (1, 8, 1, 128, 32, None),   # MQA
        (2, 4, 4, 256, 64, 64),     # MHA + window
        (1, 2, 2, 128, 128, 32),
        (1, 16, 4, 512, 64, 128),
    ],
)
def test_flash_attention(b, h, kv, s, d, win, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, window=win, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d", [(2, 8, 2, 512, 64), (1, 4, 1, 256, 128), (3, 6, 6, 512, 32)])
def test_decode_attention(b, h, kv, s, d, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    length = jnp.asarray([s // 2, s // 4, s][:b], jnp.int32)
    out = decode_attention(q, kc, vc, length, block_s=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("b,s,di,n,bt,bd", [(2, 256, 128, 16, 64, 64), (1, 128, 64, 8, 128, 64), (2, 512, 256, 16, 64, 128)])
def test_mamba_scan(b, s, di, n, bt, bd):
    from repro.kernels.mamba_scan.kernel import selective_scan
    from repro.kernels.mamba_scan.ref import selective_scan_ref

    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) * 0.5)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.2)
    D = jnp.ones((di,))
    y, h = selective_scan(x, dt, B, C, A, D, block_t=bt, block_d=bd, interpret=True)
    yr, hr = selective_scan_ref(x, dt, B, C, A, D)
    np.testing.assert_allclose(y, yr, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(h, hr, atol=5e-5, rtol=5e-5)


def test_mamba_scan_carries_state():
    """Scanning two halves with carried state == one full scan."""
    from repro.kernels.mamba_scan.kernel import selective_scan

    b, s, di, n = 1, 256, 64, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) * 0.5)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.2)
    D = jnp.ones((di,))
    y_full, h_full = selective_scan(x, dt, B, C, A, D, block_t=64, block_d=64, interpret=True)
    half = s // 2
    y1, h1 = selective_scan(x[:, :half], dt[:, :half], B[:, :half], C[:, :half], A, D,
                            block_t=64, block_d=64, interpret=True)
    y2, h2 = selective_scan(x[:, half:], dt[:, half:], B[:, half:], C[:, half:], A, D,
                            h0=h1, block_t=64, block_d=64, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(h2, h_full, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("b,s,w,bt,bw", [(2, 256, 128, 64, 64), (1, 128, 256, 128, 128), (2, 512, 64, 64, 64)])
def test_rglru_scan(b, s, w, bt, bw):
    from repro.kernels.rglru.kernel import rglru_scan
    from repro.kernels.rglru.ref import rglru_scan_ref

    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, w))
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w)))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (w,)))
    y, h = rglru_scan(x, r, i, la, block_t=bt, block_w=bw, interpret=True)
    yr, hr = rglru_scan_ref(x, r, i, la)
    np.testing.assert_allclose(y, yr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h, hr, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,d,m,bb", [(64, 35, 32, 32), (128, 16, 64, 64), (32, 8, 8, 32)])
def test_temporal_gate_cell(b, d, m, bb):
    from repro.core.gating import GateConfig, gate_specs
    from repro.kernels.temporal_gate.kernel import gate_cell
    from repro.kernels.temporal_gate.ref import gate_cell_ref
    from repro.models.params import init_params

    gcfg = GateConfig(d_feature=d, d_hidden=m)
    p = init_params(gate_specs(gcfg), jax.random.PRNGKey(3))
    dx = jax.random.normal(KEY, (b, d))
    h = jax.random.normal(KEY, (b, m)) * 0.1
    vol = jax.random.uniform(KEY, (b,))
    hn, tau, gm = gate_cell(dx, h, vol, p, block_b=bb, interpret=True)
    hr, taur, gmr = gate_cell_ref(dx, h, vol, p)
    np.testing.assert_allclose(hn, hr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tau, taur, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(gm, gmr, atol=1e-5, rtol=1e-5)


try:
    from hypothesis import given, settings
    import hypothesis.strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # optional dep: skip only the property-based test
    HAS_HYPOTHESIS = False

if not HAS_HYPOTHESIS:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flash_attention_property():
        pass

else:

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        kv=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2, 4]),
        nq=st.integers(1, 4),
        d=st.sampled_from([32, 64]),
        windowed=st.booleans(),
    )
    def test_flash_attention_property(b, kv, g, nq, d, windowed):
        """Random GQA/window geometries: kernel == oracle (property-based)."""
        from repro.kernels.flash_attention.kernel import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref

        h = kv * g
        s = 64 * nq
        win = 32 if windowed else None
        ks = jax.random.split(jax.random.PRNGKey(b * 100 + h * 10 + nq), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, kv, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, kv, s, d), jnp.float32)
        out = flash_attention(q, k, v, window=win, block_q=64, block_k=64, interpret=True)
        ref = attention_ref(q, k, v, window=win)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_gate_kernel_matches_model_cell():
    """The fused kernel must agree with the model-level gate_step (Eq. 5-6)."""
    from repro.core.gating import GateConfig, GateState, gate_specs, gate_step
    from repro.kernels.temporal_gate.ref import gate_cell_ref
    from repro.models.params import init_params

    gcfg = GateConfig(d_feature=12, d_hidden=16, var_window=4)
    p = init_params(gate_specs(gcfg), jax.random.PRNGKey(5))
    dx = jax.random.normal(KEY, (12,))
    st = GateState(
        h=jax.random.normal(KEY, (16,)) * 0.1,
        var_buf=jax.random.normal(KEY, (4, 12)) * 0.2,
        var_idx=jnp.asarray(2, jnp.int32),
    )
    new_state, (tau, gmean) = gate_step(gcfg, p, st, dx)
    # replicate volatility used by gate_step
    buf = jax.lax.dynamic_update_slice_in_dim(st.var_buf, dx[None], 2, axis=0)
    vol = jnp.var(buf, axis=0).mean()
    h_ref, tau_ref, g_ref = gate_cell_ref(dx[None], st.h[None], vol[None], p)
    np.testing.assert_allclose(new_state.h, h_ref[0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tau, tau_ref[0], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("m,p,f,bm,bf", [
    (16, 16, 50, 8, 32),    # M and F both padded (50 % 32 != 0)
    (13, 16, 50, 8, 16),    # odd M: padding path
    (8, 1, 50, 8, 64),      # P=1 degenerate pole set, F < block
    (64, 16, 128, 32, 64),  # exact tiling, multi-tile argmin hand-off
])
def test_ccg_master(m, p, f, bm, bf):
    """Pallas masked CCG master step (interpret) == jnp oracle, including the
    empty-scenario-set (η=0) and all-infeasible (obj=BIG) lanes and argmin
    ties across F tiles."""
    from repro.kernels.ccg_master.kernel import ccg_master as ccg_master_pallas
    from repro.kernels.ccg_master.ops import ccg_master
    from repro.kernels.ccg_master.ref import ccg_master_ref

    ks = jax.random.split(KEY, 4)
    rec = jax.random.uniform(ks[0], (m, p, f), jnp.float32, 0.0, 5.0)
    scen = (jax.random.uniform(ks[1], (m, p)) > 0.5).astype(jnp.float32)
    scen = scen.at[0].set(0.0)                    # empty scenario set lane
    fs_ok = jax.random.uniform(ks[2], (m, f)) > 0.3
    fs_ok = fs_ok.at[1].set(False)                # all-infeasible lane
    c1 = jax.random.uniform(ks[3], (f,), jnp.float32, 0.0, 1.0)

    y_ref, od_ref = ccg_master_ref(rec, scen, fs_ok, c1)
    y, od = ccg_master(rec, scen, fs_ok, c1, block_m=bm, block_f=bf,
                       force="pallas")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(od), np.asarray(od_ref))

    # tie-breaking: duplicate the minimum across tiles -> lowest index wins
    rec_t = jnp.zeros((4, p, f))
    c1_t = jnp.zeros((f,)).at[jnp.asarray([3, f - 2])].set(-1.0)
    y_t, _ = ccg_master(rec_t, jnp.zeros((4, p)), jnp.ones((4, f), bool), c1_t,
                        block_m=bm, block_f=bf, force="pallas")
    assert np.all(np.asarray(y_t) == 3)

    # direct kernel call on exact tiles (no ops padding) as well
    if m % bm == 0 and f % bf == 0:
        y_k, od_k = ccg_master_pallas(
            rec, scen, fs_ok.astype(jnp.float32), c1,
            block_m=bm, block_f=bf, interpret=True)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_ref))
        np.testing.assert_array_equal(np.asarray(od_k), np.asarray(od_ref))


@pytest.mark.parametrize("m,bm,gamma", [
    (16, 8, 2),     # exact tiling
    (13, 8, 2),     # odd M: ops padding path
    (7, 128, 2),    # whole batch smaller than one block
    (9, 8, 0),      # Γ=0 degenerate pole set (P=1)
])
def test_ccg_encode(m, bm, gamma):
    """Fused table-free task encoding (jnp ref + Pallas interpret) ==
    the table-based ``_encode_tasks`` oracle, bit for bit: feasibility
    bitmask, recourse slab, and the flat accuracy argmax — including an
    all-infeasible lane (fallback path) and an everything-feasible lane."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import RobustProblem, _encode_tasks
    from repro.kernels.ccg_encode.ops import ccg_encode
    from repro.kernels.ccg_encode.ref import ccg_encode_ref

    sys_ = SystemConfig(gamma=gamma)
    prob = RobustProblem.build(sys_)
    lat = prob.lat
    rng = np.random.default_rng(m * 10 + gamma)
    z = rng.uniform(0, 1, m)
    aq = rng.uniform(0.5, 0.75, m)
    aq[0] = 0.99    # all-infeasible lane: margin-relaxation fallback
    aq[1] = 0.0     # everything-feasible lane: full bitmask
    z = jnp.asarray(z, jnp.float32)
    aq = jnp.asarray(aq, jnp.float32)

    # table-based oracle
    f_flat, feas_f, fs_ok, rec_tab = _encode_tasks(prob, z, aq)
    pow2 = 2 ** jnp.arange(sys_.num_versions)
    code_tab = np.asarray((feas_f * pow2[None, None]).sum(axis=-1))
    best_tab = np.asarray(f_flat.reshape(m, -1).argmax(axis=1))
    assert not np.asarray(fs_ok)[0].any() and np.asarray(fs_ok)[1].all()

    args = (z, aq, lat.rn_flat, lat.pn_flat, lat.tier_flat,
            prob.b2_scaled, prob.rec_table)
    kw = dict(margin=sys_.acc_margin_robust, num_versions=sys_.num_versions)
    for force, blk in (("ref", 128), ("pallas", bm)):
        code, rec, best = ccg_encode(*args, block_m=blk, force=force, **kw)
        np.testing.assert_array_equal(np.asarray(code), code_tab, err_msg=force)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(rec_tab),
                                      err_msg=force)
        np.testing.assert_array_equal(np.asarray(best), best_tab, err_msg=force)

    # the raw ref entry point agrees too (no dispatch wrapper)
    code_r, rec_r, best_r = ccg_encode_ref(
        z, aq, lat.rn_flat, lat.pn_flat, lat.tier_flat, prob.rec_table,
        sys_.acc_margin_robust, sys_.num_versions)
    np.testing.assert_array_equal(np.asarray(code_r), code_tab)
    np.testing.assert_array_equal(np.asarray(rec_r), np.asarray(rec_tab))
    np.testing.assert_array_equal(np.asarray(best_r), best_tab)


def test_ccg_encode_argmax_tie_breaking():
    """The running flat argmax must break accuracy ties exactly like
    ``argmax`` over the (F·K) flat space: saturated (clipped-to-1) surfaces
    tie across many configs -> lowest flat index wins."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import RobustProblem, _encode_tasks
    from repro.kernels.ccg_encode.ops import ccg_encode

    # huge version ladder ceiling saturates accuracy at the clip for many
    # (r, p, k, tier) combos -> widespread exact ties at 1.0... the formula
    # caps a_max below 1, so instead drive z=0: accuracy is then independent
    # of p, guaranteeing Z-way exact ties at every (r, k, tier)
    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    lat = prob.lat
    m = 6
    z = jnp.zeros((m,), jnp.float32)
    aq = jnp.full((m,), 0.7, jnp.float32)
    f_flat, *_ = _encode_tasks(prob, z, aq)
    best_tab = np.asarray(f_flat.reshape(m, -1).argmax(axis=1))
    for force in ("ref", "pallas"):
        _, _, best = ccg_encode(
            z, aq, lat.rn_flat, lat.pn_flat, lat.tier_flat,
            prob.b2_scaled, prob.rec_table, block_m=8, force=force,
            margin=sys_.acc_margin_robust, num_versions=sys_.num_versions)
        np.testing.assert_array_equal(np.asarray(best), best_tab, err_msg=force)


_SOLVE_KEYS = ("route", "r", "p", "v", "o_up", "o_down", "iters", "infeasible")


@pytest.mark.parametrize("m,gamma,warm", [
    (16, 2, None),       # cold solve, exact tiling
    (13, 2, "mixed"),    # odd M: ops padding path; warm starts with -1 misses
    (9, 0, "hit"),       # Γ=0 degenerate pole set (P=1)
    (256, 2, "mixed"),   # live-lane compaction tail in the jnp ref
])
def test_ccg_solve(m, gamma, warm):
    """Fully fused CCG solver (jnp ref + Pallas interpret) == both retained
    oracles — the unrolled masked ``solve_ccg`` and the early-exit
    ``solve_ccg_while`` — bit for bit on every output: decisions, bounds,
    iteration counts, and the infeasibility flag.  Covers warm-start misses
    (-1 lanes), an all-infeasible lane, the Γ=0 single-pole degenerate set,
    and the M≥256 live-lane-compaction tail."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import (RobustProblem, solve_ccg, solve_ccg_fused,
                                   solve_ccg_while)

    sys_ = SystemConfig(gamma=gamma)
    prob = RobustProblem.build(sys_)
    rng = np.random.default_rng(m * 10 + gamma)
    z = rng.uniform(0, 1, m)
    aq = rng.uniform(0.5, 0.75, m)
    aq[0] = 0.99    # all-infeasible lane: fallback config path
    z = jnp.asarray(z, jnp.float32)
    aq = jnp.asarray(aq, jnp.float32)
    wy = None
    if warm == "mixed":
        wy = jnp.asarray(rng.integers(-1, prob.lat.n_flat, m), jnp.int32)
    elif warm == "hit":
        wy = jnp.asarray(rng.integers(0, prob.lat.n_flat, m), jnp.int32)

    unrolled = solve_ccg(prob, z, aq, warm_y=wy)
    early = solve_ccg_while(prob, z, aq, warm_y=wy)
    for force in ("ref", "pallas"):
        fused = solve_ccg_fused(prob, z, aq, warm_y=wy, force=force)
        for k in _SOLVE_KEYS:
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(unrolled[k]),
                err_msg=f"{force}:{k} vs solve_ccg")
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(early[k]),
                err_msg=f"{force}:{k} vs solve_ccg_while")


def test_ccg_solve_argmin_tie_breaking():
    """z=0 makes accuracy independent of fps -> widespread exact objective
    ties in the master argmin; the fused solver must break them at the
    lowest flat index exactly like the oracles."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import RobustProblem, solve_ccg, solve_ccg_fused

    prob = RobustProblem.build(SystemConfig())
    m = 6
    z = jnp.zeros((m,), jnp.float32)
    aq = jnp.full((m,), 0.7, jnp.float32)
    oracle = solve_ccg(prob, z, aq)
    for force in ("ref", "pallas"):
        fused = solve_ccg_fused(prob, z, aq, force=force)
        for k in _SOLVE_KEYS:
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(oracle[k]),
                err_msg=f"{force}:{k}")


@pytest.mark.parametrize("dead_tier", [0, 1])
def test_ccg_encode_masked_tier(dead_tier):
    """Scenario outage lowered to the (F,) ``y_ok`` mask: every option on
    the dead tier must drop out of the feasibility bitmask AND out of the
    all-infeasible fallback argmax — on the jnp ref and the Pallas
    interpret path, bit-identically to the table-based oracle with the
    same ``tier_ok``."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import RobustProblem, _encode_tasks
    from repro.kernels.ccg_encode.ops import ccg_encode

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    lat = prob.lat
    m = 13          # odd M also exercises the Pallas padding path
    rng = np.random.default_rng(77 + dead_tier)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = np.asarray(rng.uniform(0.5, 0.75, m), np.float32)
    aq[0] = 0.99    # all-infeasible lane: the fallback must survive masking
    aq = jnp.asarray(aq)

    tier_ok = np.ones(2, np.float32)
    tier_ok[dead_tier] = 0.0
    tier_ok = jnp.asarray(tier_ok)
    y_ok = lat.tier_y_ok(tier_ok)

    f_flat, feas_f, _, rec_tab = _encode_tasks(prob, z, aq, tier_ok=tier_ok)
    pow2 = 2 ** jnp.arange(sys_.num_versions)
    code_tab = np.asarray((feas_f * pow2[None, None]).sum(axis=-1))
    best_tab = np.asarray(f_flat.reshape(m, -1).argmax(axis=1))

    dead_cols = np.asarray(lat.tier_flat) == dead_tier
    tier_of_best = np.asarray(lat.tier_flat)[best_tab // sys_.num_versions]
    assert (code_tab[:, dead_cols] == 0).all()
    assert (tier_of_best == 1 - dead_tier).all()

    args = (z, aq, lat.rn_flat, lat.pn_flat, lat.tier_flat,
            prob.b2_scaled, prob.rec_table)
    kw = dict(margin=sys_.acc_margin_robust, num_versions=sys_.num_versions)
    for force in ("ref", "pallas"):
        code, rec, best = ccg_encode(*args, block_m=8, force=force,
                                     y_ok=y_ok, **kw)
        np.testing.assert_array_equal(np.asarray(code), code_tab, err_msg=force)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(rec_tab),
                                      err_msg=force)
        np.testing.assert_array_equal(np.asarray(best), best_tab, err_msg=force)


@pytest.mark.parametrize("dead_tier", [0, 1])
def test_ccg_solve_masked_tier(dead_tier):
    """Fused solve under a whole-tier outage == both retained oracles with
    the same ``tier_ok``, and no decision — including the all-infeasible
    fallback lane — ever lands on the dead tier."""
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import (RobustProblem, solve_ccg, solve_ccg_fused,
                                   solve_ccg_while)

    prob = RobustProblem.build(SystemConfig())
    m = 13
    rng = np.random.default_rng(88 + dead_tier)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = np.asarray(rng.uniform(0.5, 0.75, m), np.float32)
    aq[0] = 0.99    # all-infeasible lane: fallback must pick a survivor
    aq = jnp.asarray(aq)
    tier_ok = jnp.zeros(2, jnp.float32).at[1 - dead_tier].set(1.0)

    unrolled = solve_ccg(prob, z, aq, tier_ok=tier_ok)
    early = solve_ccg_while(prob, z, aq, tier_ok=tier_ok)
    assert (np.asarray(unrolled["route"]) == 1 - dead_tier).all()
    for force in ("ref", "pallas"):
        fused = solve_ccg_fused(prob, z, aq, force=force, tier_ok=tier_ok)
        assert (np.asarray(fused["route"]) == 1 - dead_tier).all(), force
        for k in _SOLVE_KEYS:
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(unrolled[k]),
                err_msg=f"{force}:{k} vs solve_ccg")
            np.testing.assert_array_equal(
                np.asarray(fused[k]), np.asarray(early[k]),
                err_msg=f"{force}:{k} vs solve_ccg_while")


@pytest.mark.parametrize("m,bm", [
    (16, 8),     # exact tiling
    (13, 8),     # odd M: ops padding path
    (7, 256),    # whole batch smaller than one block
])
def test_c6_tail(m, bm):
    """Fused C6 repair tail (jnp ref + Pallas interpret) == the inline
    ``take_along_axis`` + ``accuracy_at`` round body, bit for bit: draw,
    reclaimable gain (including -BIG infeasible-demotion lanes), and the
    fps-vs-resolution demotion choice."""
    from repro.core.cost_model import SystemConfig, accuracy_at, fps_norm, res_norm
    from repro.core.lattice import DecisionLattice
    from repro.core.robust import BIG
    from repro.kernels.c6_tail.ops import c6_tail

    sys_ = SystemConfig()
    lat = DecisionLattice.build(sys_)
    rng = np.random.default_rng(m)
    z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
    r = jnp.asarray(rng.integers(0, sys_.n_res, m), jnp.int32)
    p = jnp.asarray(rng.integers(0, sys_.n_fps, m), jnp.int32)
    v = jnp.asarray(rng.integers(0, sys_.num_versions, m), jnp.int32)
    route = jnp.asarray(rng.integers(0, 2, m), jnp.int32)
    r = r.at[0].set(0)   # p floor lane
    p = p.at[0].set(0)   # ... gain must fall through to -BIG
    acc_thr = aq + sys_.acc_margin_robust

    panel = jnp.moveaxis(lat.bw, -1, 0)[route].reshape(m, -1)
    take = lambda ri, pi: jnp.take_along_axis(
        panel, (ri * sys_.n_fps + pi)[:, None], axis=1)[:, 0]
    bw_o = take(r, p)
    p_dn = jnp.maximum(p - 1, 0)
    r_dn = jnp.maximum(r - 1, 0)
    can_p_o = (p > 0) & (accuracy_at(sys_, z, r, p_dn, v, route) >= acc_thr)
    can_r_o = (r > 0) & (accuracy_at(sys_, z, r_dn, p, v, route) >= acc_thr)
    gain_o = jnp.where(can_p_o, bw_o - take(r, p_dn),
                       jnp.where(can_r_o, bw_o - take(r_dn, p), -BIG))

    for force in ("ref", "pallas"):
        bw, gain, can_p = c6_tail(
            panel, r, p, v, route, z, acc_thr, res_norm(sys_), fps_norm(sys_),
            n_fps=sys_.n_fps, block_m=bm, force=force)
        np.testing.assert_array_equal(np.asarray(bw), np.asarray(bw_o),
                                      err_msg=force)
        np.testing.assert_array_equal(np.asarray(gain), np.asarray(gain_o),
                                      err_msg=force)
        np.testing.assert_array_equal(np.asarray(can_p), np.asarray(can_p_o),
                                      err_msg=force)
