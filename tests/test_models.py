"""Per-architecture smoke tests (required): reduced configs of each family
run one forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill->decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Ctx, cache_specs, decode_step, forward, loss_fn, model_specs, prefill
from repro.models.layers import output_weights
from repro.models.model import logits_last
from repro.models.params import count_params, init_params

B, S = 2, 32


def _batch(cfg, rng, with_labels=True):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = toks[:, :-1]
    else:
        batch["embeddings"] = jax.random.normal(rng, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (B, 3, S)
        )
    if with_labels:
        batch["labels"] = toks[:, 1:]
    return batch, toks


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(model_specs(cfg), rng)
    ctx = Ctx(cfg=cfg)
    batch, _ = _batch(cfg, rng)
    x, cache, aux = jax.jit(lambda p, b: forward(ctx, p, b))(params, batch)
    assert x.shape == (B, S, cfg.d_model)
    assert jnp.isfinite(x.astype(jnp.float32)).all(), f"{arch}: NaN in hidden states"
    loss, metrics = jax.jit(lambda p, b: loss_fn(ctx, p, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: NaN loss"
    assert loss.shape == ()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    from repro.train import optimizer as opt
    from repro.train.optimizer import AdamWConfig

    cfg = get_smoke_config(arch)
    params = init_params(model_specs(cfg), rng)
    ctx = Ctx(cfg=cfg)
    batch, _ = _batch(cfg, rng)

    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), grads = jax.value_and_grad(lambda q: loss_fn(ctx, q, b), has_aux=True)(p)
        new_p, new_s, m = opt.update(ocfg, grads, s, p)
        return new_p, new_s, loss, m

    new_params, new_state, loss, metrics = step(params, state, batch)
    assert jnp.isfinite(loss)
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.max(jnp.abs(a - b_))), params, new_params
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(1))
    ctx = Ctx(cfg=cfg)
    batch, toks = _batch(cfg, rng, with_labels=False)
    logits_pre, cache = jax.jit(lambda p, b: prefill(ctx, p, b))(params, batch)
    assert logits_pre.shape == (B, cfg.vocab_size)
    assert cache["length"] == S

    if cfg.embed_inputs:
        dec_in = {"tokens": toks[:, S : S + 1]}
        full_in = {"tokens": toks[:, : S + 1]}
    else:
        emb1 = jax.random.normal(jax.random.PRNGKey(7), (B, 1, cfg.d_model)).astype(jnp.bfloat16)
        dec_in = {"embeddings": emb1}
        full_in = {"embeddings": jnp.concatenate([batch["embeddings"], emb1], 1)}
        if cfg.mrope:
            dec_in["positions"] = jnp.full((B, 3, 1), S, jnp.int32)
            full_in["positions"] = jnp.broadcast_to(
                jnp.arange(S + 1, dtype=jnp.int32)[None, None], (B, 3, S + 1)
            )
    logits_dec, cache2 = jax.jit(lambda p, c, b: decode_step(ctx, p, c, b))(params, cache, dec_in)
    assert cache2["length"] == S + 1

    ctx_p = dataclasses.replace(ctx, mode="prefill")
    x_full, _, _ = jax.jit(lambda p, b: forward(ctx_p, p, b, emit_cache=True))(params, full_in)
    logits_full = logits_last(ctx, x_full[:, -1:], output_weights(cfg, params["embed"]))
    rel = float(jnp.max(jnp.abs(logits_dec - logits_full))) / (
        float(jnp.max(jnp.abs(logits_full))) + 1e-9
    )
    assert rel < 0.06, f"{arch}: decode/full mismatch rel={rel}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_parameter_count(arch):
    """Analytic count matches the built spec tree for the FULL config
    (no allocation — specs only)."""
    cfg = get_config(arch)
    specs = model_specs(cfg)
    assert count_params(specs) == cfg.param_count(), arch


def test_cache_specs_shapes():
    cfg = get_smoke_config("mixtral-8x22b")
    cs = cache_specs(cfg, batch=4, seq_len=64)
    # window cache must be bounded by attn_window; layout (L, B, C, KV, HD)
    k_spec = cs["segments"][0]["pos0"]["k"]
    assert k_spec.shape[0] == cfg.num_layers
    assert k_spec.shape[1] == 4
    assert k_spec.shape[2] == min(cfg.attn_window, 64)
    assert cs["length"].shape == ()
