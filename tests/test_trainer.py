"""Trainer: convergence, failure/resume continuity, gradient compression."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.runtime.cluster import ClusterSim, FailureInjector, elastic_remesh
from repro.train.compression import compress, decompress, ef_compress_grads
from repro.train.optimizer import AdamWConfig, lr_at
from repro.train.trainer import NodeFailure, TrainConfig, Trainer

CKPT = "results/_test_trainer_ckpt"


@pytest.fixture(autouse=True)
def _clean():
    shutil.rmtree(CKPT, ignore_errors=True)
    yield
    shutil.rmtree(CKPT, ignore_errors=True)


def _setup(steps=40, **kw):
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(steps=steps, ckpt_every=10, ckpt_dir=CKPT, log_every=5,
                       opt=AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=steps), **kw)
    data = iter(TokenPipeline(cfg.vocab_size, 64, 4, seed=0))
    return cfg, tcfg, data


def test_loss_decreases():
    cfg, tcfg, data = _setup(steps=40)
    tr = Trainer(cfg, tcfg)
    _, hist = tr.run(data)
    first = np.mean([h["loss"] for h in hist[:2]])
    last = np.mean([h["loss"] for h in hist[-2:]])
    assert last < first, (first, last)


def test_failure_resume_continuity():
    cfg, tcfg, data = _setup(steps=30)
    inj = FailureInjector(schedule={17: "node 1 lost"})
    tr = Trainer(cfg, tcfg, failure_injector=inj)
    with pytest.raises(NodeFailure):
        tr.run(data)
    assert tr.ckpt.latest_step() == 10
    # fresh trainer resumes from step 10 and reaches 40
    tr2 = Trainer(cfg, tcfg)
    _, hist = tr2.run(data)
    assert tr2.step == 40
    assert hist[0]["step"] > 10


def test_grad_accumulation_matches_full_batch():
    """grad_accum=4 on one batch == single full-batch step (same update)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.data.tokens import TokenPipeline as TP

    # fp32 compute: in bf16, Adam's sign-like first step amplifies tiny
    # grad-accumulation-order differences to ~2x lr
    cfg = dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), compute_dtype="float32")
    batch = next(iter(TP(cfg.vocab_size, 32, 8, seed=3)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    outs = {}
    for accum in (1, 4):
        tcfg = TrainConfig(steps=1, ckpt_every=100, ckpt_dir=CKPT + f"_{accum}",
                           grad_accum=accum,
                           opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=2))
        tr = Trainer(cfg, tcfg)
        params, opt_state, err = tr.init_state(jax.random.PRNGKey(9))
        new_params, *_ = tr._step_fn(params, opt_state, err, batch)
        outs[accum] = new_params

    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), outs[1], outs[4]
    )
    worst = max(jax.tree_util.tree_leaves(diffs))
    # identical up to fp accumulation-order differences
    assert worst < 5e-5, worst


def test_grad_compression_trains():
    cfg, tcfg, data = _setup(steps=30, grad_compression=True)
    tr = Trainer(cfg, tcfg)
    _, hist = tr.run(data)
    assert np.mean([h["loss"] for h in hist[-2:]]) < np.mean([h["loss"] for h in hist[:2]])


def test_compress_roundtrip_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 0.1
    q, s = compress(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(decompress(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7  # half-ulp of the int8 grid


def test_error_feedback_is_lossless_over_time():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = jax.random.PRNGKey(1)
    true_sum = jnp.zeros((32,))
    wire_sum = jnp.zeros((32,))
    err = None
    for i in range(50):
        rng, k = jax.random.split(rng)
        g = {"w": jax.random.normal(k, (32,)) * 0.01}
        wire, err = ef_compress_grads(g, err)
        true_sum = true_sum + g["w"]
        wire_sum = wire_sum + wire["w"]
    # residual error is bounded by the last quantization step, not O(T)
    resid = float(jnp.max(jnp.abs(true_sum - wire_sum)))
    assert resid < 5e-4, resid


def test_lr_schedule_shape():
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_at(ocfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_cluster_sim_heartbeats():
    c = ClusterSim(n_nodes=4, heartbeat_timeout=2.0)
    c.tick(1.0)
    dead = c.tick(1.0, heartbeats={0, 1, 2})  # node 3 silent
    assert dead == set()
    dead = c.tick(1.5, heartbeats={0, 1, 2})
    assert dead == {3}
    assert c.alive == 3


def test_elastic_remesh_shapes():
    mesh = elastic_remesh(1)
    assert mesh.devices.size == 1
    assert set(mesh.axis_names) == {"data", "model"}
