"""Policy-protocol tests: every compiled ``Policy.decide`` against its
retained host-closure oracle (decision for decision over a seeded multi-round
trace), scan-compatibility under the ``ServeSession`` driver, and the
registry smoke run CI gates on (an unregistered or scan-incompatible policy
fails here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import SystemConfig
from repro.serving.baselines import make_method
from repro.serving.policy import POLICIES, Observation, make_policy
from repro.serving.session import ServeSession
from repro.serving.simulator import SimConfig, Simulator

SYS = SystemConfig()
ORACLE_NAMES = ("A2", "JCAB", "RDAP", "Sniper", "R2E-VID")


def _trace(n_rounds=20, n_tasks=14, seed=11, requirement="fluctuating"):
    sim = Simulator(SYS, SimConfig(n_rounds=n_rounds, n_tasks=n_tasks,
                                   seed=seed, bw_fluctuation=0.2,
                                   requirement=requirement))
    return sim, [sim.sample_round() for _ in range(n_rounds)]


def _assert_trace_parity(name, rnds, n_tasks, **kw):
    """Drive the host closure and the compiled decide side by side."""
    method = make_method(name, SYS, **kw)
    policy = make_policy(name, SYS, **kw)
    host_state = {}
    st = policy.init(n_tasks)
    decide = jax.jit(policy.decide, donate_argnums=(0,))
    for i, rnd in enumerate(rnds):
        cfg = method(rnd, host_state)
        obs = Observation(z=jnp.asarray(rnd["z"]), aq=jnp.asarray(rnd["aq"]))
        st, sol = decide(st, obs)
        for k in ("route", "r", "p", "v"):
            np.testing.assert_array_equal(
                np.asarray(cfg[k]), np.asarray(sol[k]),
                err_msg=f"{name} round {i} key {k}")


@pytest.mark.parametrize("name", ORACLE_NAMES)
def test_policy_matches_host_closure_trace(name):
    """Compiled decide == numpy host closure, decision for decision, over a
    20-round seeded trace.  Covers rdap's EMA carry across rounds (the
    forecast depends on the whole history) and sniper's first-round profile
    table (reuse + far-refresh on every later round)."""
    _, rnds = _trace()
    _assert_trace_parity(name, rnds, 14)


def test_rdap_ema_carry_actually_matters():
    """Guard against a trivially-passing parity test: rdap's forecast must
    differ from the instantaneous difficulty after round 0 (i.e. the EMA
    carry is exercised, not bypassed)."""
    _, rnds = _trace(n_rounds=4)
    policy = make_policy("rdap", SYS)
    st = policy.init(14)
    fresh = make_policy("rdap", SYS)
    diffs = 0
    for rnd in rnds:
        obs = Observation(z=jnp.asarray(rnd["z"]), aq=jnp.asarray(rnd["aq"]))
        st, sol = policy.decide(st, obs)
        _, sol_fresh = fresh.decide(fresh.init(14), obs)
        for k in ("route", "r", "p", "v"):
            if not np.array_equal(np.asarray(sol[k]), np.asarray(sol_fresh[k])):
                diffs += 1
    assert diffs > 0, "EMA carry never changed a decision — trace too easy"


def test_sniper_profile_table_frozen_after_first_round():
    """The profile table is captured on round 0 and never rewritten."""
    _, rnds = _trace(n_rounds=3)
    policy = make_policy("sniper", SYS)
    st = policy.init(14)
    obs0 = Observation(z=jnp.asarray(rnds[0]["z"]), aq=jnp.asarray(rnds[0]["aq"]))
    st, _ = policy.decide(st, obs0)
    key_after_0 = np.asarray(st.key).copy()
    assert np.isfinite(key_after_0[: policy.n_profiles]).all()
    for rnd in rnds[1:]:
        obs = Observation(z=jnp.asarray(rnd["z"]), aq=jnp.asarray(rnd["aq"]))
        st, _ = policy.decide(st, obs)
    np.testing.assert_array_equal(np.asarray(st.key), key_after_0)


@pytest.mark.parametrize("kw", [{"use_stage1": False}, {"use_stage2": False}])
def test_r2evid_ablation_policies_match_host(kw):
    """The §4.4 ablation flags port decision-identically."""
    _, rnds = _trace(n_rounds=6)
    _assert_trace_parity("R2E-VID", rnds, 14, **kw)


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_registered_policy_serves_through_session(name):
    """CI's session-parity smoke: every registered policy must (a) build
    from the registry, (b) run compiled under the single ``ServeSession.run``
    scan, and (c) agree ≤1e-5 with its host-loop oracle's metrics on the
    same rounds.  A policy that is not scan-compatible or whose decisions
    drift from the oracle fails the build here."""
    scfg = SimConfig(n_rounds=6, n_tasks=10, seed=5, bw_fluctuation=0.15,
                     requirement="fluctuating")
    sim = Simulator(SYS, scfg)
    stream = sim.sample_stream()
    policy = make_policy(name, SYS)
    session = ServeSession(policy, n_streams=scfg.n_tasks, sim=scfg)
    mets = session.run(stream)
    assert np.isfinite(np.asarray(mets["cost"])).all()
    assert np.asarray(mets["cost"]).shape == (scfg.n_rounds, scfg.n_tasks)

    # host-loop oracle: the retained closure + the simulator's deterministic
    # realization, round by round
    sim_b = Simulator(SYS, scfg)
    rnds = [sim_b.sample_round() for _ in range(scfg.n_rounds)]
    method = make_method(name, SYS)
    host_state = {}
    for i, rnd in enumerate(rnds):
        cfg = method(rnd, host_state)
        met = sim_b._realize_deterministic(rnd, cfg)
        for k in ("delay", "energy", "cost", "accuracy"):
            np.testing.assert_allclose(
                np.asarray(mets[k][i]), met[k], atol=1e-5,
                err_msg=f"{name} round {i} {k}")


def test_policy_decide_scan_equals_sequential():
    """``decide`` under one ``lax.scan`` == the same decides issued one at a
    time — the scan-compatibility contract of the protocol (stateful
    policies included)."""
    scfg = SimConfig(n_rounds=5, n_tasks=8, seed=3, bw_fluctuation=0.1)
    sim = Simulator(SYS, scfg)
    stream = sim.sample_stream()
    for name in ("rdap", "sniper", "r2evid"):
        policy = make_policy(name, SYS)

        def body(st, obs):
            return policy.decide(st, obs)

        st_scan, sols = jax.lax.scan(
            body, policy.init(scfg.n_tasks),
            Observation(z=stream.z, aq=stream.aq))
        st_seq = policy.init(scfg.n_tasks)
        for i in range(scfg.n_rounds):
            obs = Observation(z=stream.z[i], aq=stream.aq[i])
            st_seq, sol = policy.decide(st_seq, obs)
            for k in ("route", "r", "p", "v"):
                np.testing.assert_array_equal(
                    np.asarray(sols[k][i]), np.asarray(sol[k]),
                    err_msg=f"{name} round {i} {k}")
        for a, b in zip(jax.tree_util.tree_leaves(st_scan),
                        jax.tree_util.tree_leaves(st_seq)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_make_policy_aliases_and_unknown():
    assert make_policy("A2", SYS).name == "a2_cloud_only"
    assert make_policy("r2evid", SYS).name == "r2evid"
    with pytest.raises(KeyError):
        make_policy("no-such-policy", SYS)
