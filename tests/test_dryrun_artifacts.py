"""Validate collected multi-pod dry-run artifacts (skips if not yet run).

The dry-run itself needs 512 fake devices and must run as its own process:
  PYTHONPATH=src python -m repro.launch.dryrun
"""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import applicable_shapes

OUT = "results/dryrun"

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(OUT, "*.json")),
    reason="dry-run artifacts not collected (run repro.launch.dryrun)",
)


def _cells(mesh):
    out = {}
    for f in glob.glob(os.path.join(OUT, f"*__{mesh}.json")):
        r = json.load(open(f))
        if r.get("tag"):
            continue  # hillclimb variants tracked separately
        out[(r["arch"], r["shape"])] = r
    return out


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_every_applicable_cell_compiled(mesh):
    cells = _cells(mesh)
    missing, failed = [], []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            r = cells.get((arch, shape))
            if r is None:
                missing.append((arch, shape))
            elif r["status"] != "ok":
                failed.append((arch, shape, r.get("error")))
    assert not missing, f"cells never dry-run: {missing}"
    assert not failed, f"cells failed to compile: {failed}"


def test_long500k_only_for_subquadratic():
    cells = _cells("single")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        has = (arch, "long_500k") in cells
        assert has == cfg.sub_quadratic, (arch, has, cfg.sub_quadratic)


def test_roofline_terms_present_and_positive():
    for (arch, shape), r in _cells("single").items():
        t = r["terms"]
        assert t["compute_s"] > 0 or shape.startswith("decode") or shape == "long_500k"
        assert t["memory_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")


def test_multi_pod_uses_512_chips():
    for r in _cells("multi").values():
        assert r["chips"] == 512
    for r in _cells("single").values():
        assert r["chips"] == 256
