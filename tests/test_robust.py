"""Property-based tests for the two-stage robust optimizer (Eq. 2-10, Alg. 2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.cost_model import SystemConfig, accuracy_table
from repro.core.robust import BIG, RobustProblem, exact_oracle, solve_ccg, total_cost

SYS = SystemConfig()
PROB = RobustProblem.build(SYS)


@settings(max_examples=20, deadline=None)
@given(
    z=st.lists(st.floats(0.0, 1.0), min_size=4, max_size=16),
    aq=st.lists(st.floats(0.45, 0.82), min_size=4, max_size=16),
)
def test_ccg_matches_exact_oracle(z, aq):
    n = min(len(z), len(aq))
    z = jnp.asarray(z[:n], jnp.float32)
    aq = jnp.asarray(aq[:n], jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    y, obj = exact_oracle(PROB, z, aq)
    feasible = ~np.asarray(sol["infeasible"])
    gap = np.abs(np.asarray(sol["o_up"] - obj))[feasible]
    assert gap.size == 0 or gap.max() < 1e-5


@settings(max_examples=20, deadline=None)
@given(
    z=st.lists(st.floats(0.0, 1.0), min_size=4, max_size=12),
    aq=st.lists(st.floats(0.45, 0.75), min_size=4, max_size=12),
)
def test_upper_bound_dominates_lower(z, aq):
    n = min(len(z), len(aq))
    sol = solve_ccg(PROB, jnp.asarray(z[:n], jnp.float32), jnp.asarray(aq[:n], jnp.float32))
    assert np.all(np.asarray(sol["o_up"]) >= np.asarray(sol["o_down"]) - 1e-6)


@settings(max_examples=15, deadline=None)
@given(
    z=st.lists(st.floats(0.0, 1.0), min_size=4, max_size=10),
    aq=st.lists(st.floats(0.45, 0.72), min_size=4, max_size=10),
    pole_idx=st.integers(0, 15),
)
def test_robust_guarantee_under_any_pole(z, aq, pole_idx):
    """Realized cost under any u in the pole set never exceeds O_up for the
    task's chosen configuration (that's what 'robust' means)."""
    n = min(len(z), len(aq))
    z = jnp.asarray(z[:n], jnp.float32)
    aq = jnp.asarray(aq[:n], jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    pole = PROB.poles[pole_idx % PROB.poles.shape[0]]
    u = pole * PROB.u_dev
    realized = total_cost(PROB, sol, z, aq, u=np.asarray(u))
    feasible = ~np.asarray(sol["infeasible"])
    bad = np.asarray(realized)[feasible] > np.asarray(sol["o_up"])[feasible] + 1e-5
    assert not bad.any()


def test_gamma_monotonicity():
    """Larger uncertainty budget Γ can only increase the robust objective."""
    z = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 32), jnp.float32)
    aq = jnp.asarray(np.random.default_rng(1).uniform(0.5, 0.75, 32), jnp.float32)
    prev = None
    for gamma in (0, 1, 2, 5):
        prob = RobustProblem.build(dataclasses.replace(SYS, gamma=gamma))
        sol = solve_ccg(prob, z, aq)
        cur = np.asarray(sol["o_up"])
        if prev is not None:
            assert np.all(cur >= prev - 1e-6), f"gamma={gamma} decreased objective"
        prev = cur


def test_feasibility_is_respected():
    z = jnp.asarray([0.2, 0.5, 0.9], jnp.float32)
    aq = jnp.asarray([0.6, 0.65, 0.7], jnp.float32)
    sol = solve_ccg(PROB, z, aq)
    f = np.asarray(accuracy_table(SYS, z))
    idx = np.arange(3)
    acc = f[idx, np.asarray(sol["r"]), np.asarray(sol["p"]), np.asarray(sol["v"]),
            np.asarray(sol["route"])]
    infeasible = np.asarray(sol["infeasible"])
    assert np.all(acc[~infeasible] >= np.asarray(aq)[~infeasible] + SYS.acc_margin_robust - 1e-6)


def test_infeasible_fallback_maximizes_accuracy():
    z = jnp.asarray([1.0], jnp.float32)
    aq = jnp.asarray([0.99], jnp.float32)  # unattainable
    sol = solve_ccg(PROB, z, aq)
    assert bool(sol["infeasible"][0])
    f = np.asarray(accuracy_table(SYS, z))[0]
    chosen = f[int(sol["r"][0]), int(sol["p"][0]), int(sol["v"][0]), int(sol["route"][0])]
    assert chosen >= f.max() - 1e-6


def test_poles_respect_gamma_budget():
    for gamma in (0, 1, 2, 3):
        prob = RobustProblem.build(dataclasses.replace(SYS, gamma=gamma))
        assert np.all(np.asarray(prob.poles).sum(axis=1) <= gamma)
