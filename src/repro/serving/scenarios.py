"""Scenario engine: compiled fault injection for serving runs (paper §4.3).

A :class:`ScenarioTrace` compiles an adverse serving condition — tier
outages, bandwidth collapse, heavy-tailed stragglers, adversarial compute
deviations — into *per-round arrays* that ride on the round-stacked
:class:`~repro.serving.policy.Observation`.  ``apply_scenario`` merges the
trace into a sampled stream; the session then serves the whole degraded run
inside its ONE ``lax.scan`` — no per-round Python, no special-cased drivers:

  ``tier_ok``  (R, 2)     router-visible availability: outaged tiers become
                          infeasible in Stage-1/CCG/C6 and are clamped away
                          post temporal consistency
  ``avail``    (R, S)     realization-visible per-server availability: dead
                          servers take no LPT load, the tier uplink shrinks
                          by the alive fraction
  ``bw_mult``  (R, 2)     multiplicative bandwidth trace composed onto the
                          stream's sampled fluctuation (collapse/recovery
                          ramps, flash-crowd spikes)
  ``bw_scale`` (R,)       the C6 budget scale the repair pass *plans*
                          against — capacity knowledge, not adversary state
  ``u``        (R, K)     realized compute-deviation schedule (adversarial
                          rotation saturating the Γ budget)
  ``lat_mult`` (R, M, 2)  heavy-tailed latency multipliers; with the
                          session's static ``hedge=(quantile, cost)`` the
                          realization races a backup replica per straggler

Traces are compiled host-side with a seeded numpy rng (a scenario is data,
not traced control flow), so a (name, shape, seed) triple is reproducible
everywhere — the golden suite in ``benchmarks/scenario_suite.py`` pins it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import SystemConfig
from repro.core.lattice import version_deviations
from repro.serving.policy import Observation, make_policy
from repro.serving.session import AdmissionConfig
from repro.serving.simulator import SimConfig, Simulator

#: the named adverse suite (``none`` is the benign control)
SUITE = ("edge_outage", "bw_collapse", "flash_crowd", "straggler_tail",
         "adversarial_u", "churn", "flash_churn", "markov_bw",
         "outage_collapse")

#: Pareto tail index for straggler latency draws (heavy: infinite variance)
_PARETO_ALPHA = 1.5
_LAT_CLIP = 20.0

#: re-serve premium per SLA-violated segment: a missed requirement means the
#: segment is served again at high fidelity (~2x the benign per-segment
#: cost).  ``sla_cost = cost + SLA_PENALTY * sla_violation_rate`` is the
#: suite's comparison metric — raw cost alone would reward under-provisioned
#: baselines for shipping accuracy misses.
SLA_PENALTY = 10.0


@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """One compiled scenario: per-round fault arrays + hedge policy.

    Every array field is optional; ``None`` means "benign along that axis"
    and leaves the corresponding Observation field untouched, so the
    ``none`` trace reproduces the pre-scenario program bit for bit.
    ``onset`` is the first degraded round (None for always-on scenarios) —
    the anchor for the recovery-rounds metric.
    """
    name: str
    onset: Optional[int] = None
    tier_ok: Any = None     # (R, 2)
    avail: Any = None       # (R, S)
    bw_mult: Any = None     # (R, 2) multiplier composed onto the stream's
    bw_scale: Any = None    # (R,)
    u: Any = None           # (R, K) replaces the stream's realized u
    lat_mult: Any = None    # (R, M, 2)
    hedge: Optional[tuple] = None   # static (quantile, cost)
    arrive_n: Any = None    # (R,) stream arrivals per round (churn)
    depart: Any = None      # (R, M) per-slot departure events (churn)
    admission: Optional[AdmissionConfig] = None   # static admission knobs


# ---------------------------------------------------------------------------
# builders (host-side, seeded numpy)
# ---------------------------------------------------------------------------
def _none(r, m, n_edge, n_cloud, sys, rng):
    return ScenarioTrace(name="none")


def _cap_frac(sys, edge_frac, cloud_frac):
    """Uplink capacity fraction given per-tier alive/throughput fractions —
    the ``bw_scale`` telemetry a capacity-aware repair plans against."""
    cap = sys.edge_bw_mbps + sys.cloud_bw_mbps
    return (sys.edge_bw_mbps * edge_frac + sys.cloud_bw_mbps * cloud_frac) / cap


def _edge_outage(r, m, n_edge, n_cloud, sys, rng):
    """The edge tier dies at r0 = R//3; servers recover staggered, one
    every other round.  The health gate (``tier_ok``) readmits the tier at
    quorum (half the pool alive) — a tier at 1/4 capacity is not
    schedulable, or the flood-back crushes the lone survivor.  ``bw_scale``
    carries the alive-weighted capacity fraction (server counts are
    observable telemetry) for the repair pass."""
    r0 = max(1, r // 3)
    avail = np.ones((r, n_edge + n_cloud), np.float32)
    for i in range(n_edge):
        rec = min(r, r0 + 2 + 2 * i)         # server i back at r0+2+2i
        avail[r0:rec, i] = 0.0
    alive_e = avail[:, :n_edge].mean(axis=1)
    tier_ok = np.ones((r, 2), np.float32)
    tier_ok[:, 0] = (alive_e >= 0.5).astype(np.float32)   # quorum gate
    return ScenarioTrace(
        name="edge_outage", onset=r0, tier_ok=tier_ok, avail=avail,
        bw_scale=_cap_frac(sys, alive_e, 1.0).astype(np.float32))


def _bw_collapse(r, m, n_edge, n_cloud, sys, rng):
    """WAN congestion: the *cloud* uplink ramps down to a 0.15 floor, holds,
    and ramps back (edge links are local and keep their rate).  ``bw_scale``
    hands the capacity trace to the C6 repair so a capacity-aware policy
    plans against the scarcity instead of discovering it."""
    r0 = max(1, r // 3)
    ramp = max(2, r // 8)
    hold = max(2, r // 6)
    floor = 0.15
    trace = np.ones((r,), np.float32)
    for i in range(ramp):                     # down-ramp
        if r0 + i < r:
            trace[r0 + i] = 1.0 - (1.0 - floor) * (i + 1) / ramp
    lo, hi = min(r, r0 + ramp), min(r, r0 + ramp + hold)
    trace[lo:hi] = floor
    for i in range(ramp):                     # recovery ramp
        t = r0 + ramp + hold + i
        if t < r:
            trace[t] = floor + (1.0 - floor) * (i + 1) / ramp
    bw_mult = np.stack([np.ones((r,), np.float32), trace], axis=1)
    return ScenarioTrace(
        name="bw_collapse", onset=r0, bw_mult=bw_mult,
        bw_scale=_cap_frac(sys, 1.0, trace).astype(np.float32))


def _flash_crowd(r, m, n_edge, n_cloud, sys, rng):
    """Short repeated contention spikes: three 2-round windows where cross
    traffic takes ~65% of both uplinks.  Again mirrored into ``bw_scale``."""
    trace = np.ones((r,), np.float32)
    r0 = max(1, r // 4)
    starts = sorted(rng.choice(np.arange(r0, max(r0 + 1, r - 2)),
                               size=min(3, max(1, r - r0 - 2)),
                               replace=False))
    for s in starts:
        trace[s:s + 2] = 0.35
    bw_mult = np.repeat(trace[:, None], 2, axis=1)
    return ScenarioTrace(name="flash_crowd", onset=int(starts[0]),
                         bw_mult=bw_mult, bw_scale=trace.copy())


def _straggler_tail(r, m, n_edge, n_cloud, sys, rng):
    """Heavy-tailed (Pareto α=1.5) per-task compute latency multipliers on
    the primary replica, an independent draw for the backup; realized with
    hedged dispatch at the 0.9 deadline quantile."""
    u = rng.uniform(size=(r, m, 2))
    lat = np.clip((1.0 - u) ** (-1.0 / _PARETO_ALPHA), 1.0, _LAT_CLIP)
    return ScenarioTrace(name="straggler_tail",
                         lat_mult=lat.astype(np.float32),
                         hedge=(0.9, 0.05))


def _adversarial_u(r, m, n_edge, n_cloud, sys, rng):
    """Realized compute deviation saturating the Γ budget every round, the
    hit set rotating across versions — the schedule a nominal planner is
    always wrong about somewhere."""
    k = sys.num_versions
    udev = np.asarray(version_deviations(sys))
    u = np.zeros((r, k), np.float32)
    for t in range(r):
        hit = [(t + j) % k for j in range(sys.gamma)]
        u[t, hit] = udev[hit]
    return ScenarioTrace(name="adversarial_u", u=u)


def _churn(r, m, n_edge, n_cloud, sys, rng):
    """Steady-state slot-pool churn: Poisson(λ = M/10) stream arrivals per
    round against memoryless per-slot departures (p = 1/8, i.e. geometric
    lifetimes with mean 8 rounds — exact regardless of when a stream was
    admitted).  The pool starts half-full so the first rounds exercise
    admission growth, not just replacement."""
    lam = max(1.0, m / 10)
    arrive = rng.poisson(lam, size=r).astype(np.int32)
    depart = rng.random((r, m)) < (1.0 / 8.0)
    return ScenarioTrace(name="churn", arrive_n=arrive, depart=depart,
                         admission=AdmissionConfig(init_alive=m // 2))


def _flash_churn(r, m, n_edge, n_cloud, sys, rng):
    """Flash-crowd arrivals co-timed with bandwidth contention: a base
    Poisson(2) trickle plus three bursts of M/2 streams, each landing as
    both uplinks dip to 0.4x for 3 rounds — the window where the admission
    controller must queue and degrade rather than admit into scarcity
    (0.4 < the default ``degrade_frac``)."""
    arrive = rng.poisson(2.0, size=r).astype(np.int32)
    r0 = max(2, r // 5)
    gap = max(3, r // 4)
    bursts = [b for b in (r0, r0 + gap, r0 + 2 * gap) if b < r]
    trace = np.ones((r,), np.float32)
    for b in bursts:
        arrive[b] += m // 2
        trace[b:b + 3] = 0.4
    bw_mult = np.repeat(trace[:, None], 2, axis=1)
    depart = rng.random((r, m)) < (1.0 / 6.0)
    return ScenarioTrace(
        name="flash_churn", onset=int(bursts[0]), bw_mult=bw_mult,
        bw_scale=trace.copy(), arrive_n=arrive, depart=depart,
        admission=AdmissionConfig(init_alive=m // 2, max_queue=m))


def _markov_bw(r, m, n_edge, n_cloud, sys, rng):
    """Gilbert-Elliott bandwidth: the cloud uplink follows a two-state
    Markov chain (good -> bad with p=0.15, bad -> good with p=0.35; the bad
    state runs at 0.3x) — correlated fade-and-recover bursts rather than
    i.i.d. fluctuation, so a policy that reacts per-round keeps arriving
    one round late.  ``bw_scale`` mirrors the chain into the repair pass."""
    p_gb, p_bg, bad_mult = 0.15, 0.35, 0.3
    trace = np.ones((r,), np.float32)
    state = 0                         # 0 = good, 1 = bad
    for t in range(r):
        flip = rng.random()
        state = (1 if flip < p_gb else 0) if state == 0 else \
                (0 if flip < p_bg else 1)
        trace[t] = bad_mult if state else 1.0
    bad = np.nonzero(trace < 1.0)[0]
    bw_mult = np.stack([np.ones((r,), np.float32), trace], axis=1)
    return ScenarioTrace(
        name="markov_bw", onset=int(bad[0]) if bad.size else None,
        bw_mult=bw_mult,
        bw_scale=_cap_frac(sys, 1.0, trace).astype(np.float32))


def _outage_collapse(r, m, n_edge, n_cloud, sys, rng):
    """Correlated co-occurring faults: the edge tier dies at R//3 *while*
    the cloud uplink collapses on the same schedule — the flood-back tier
    has no spare capacity to absorb the refugees.  ``bw_scale`` carries the
    joint capacity fraction so a capacity-aware repair plans against both
    faults at once; single-fault scenarios each understate this regime."""
    eo = _edge_outage(r, m, n_edge, n_cloud, sys, rng)
    bc = _bw_collapse(r, m, n_edge, n_cloud, sys, rng)
    alive_e = np.asarray(eo.avail)[:, :n_edge].mean(axis=1)
    cloud_trace = np.asarray(bc.bw_mult)[:, 1]
    return ScenarioTrace(
        name="outage_collapse", onset=min(eo.onset, bc.onset),
        tier_ok=eo.tier_ok, avail=eo.avail, bw_mult=bc.bw_mult,
        bw_scale=_cap_frac(sys, alive_e, cloud_trace).astype(np.float32))


SCENARIOS = {
    "none": _none,
    "edge_outage": _edge_outage,
    "bw_collapse": _bw_collapse,
    "flash_crowd": _flash_crowd,
    "straggler_tail": _straggler_tail,
    "adversarial_u": _adversarial_u,
    "churn": _churn,
    "flash_churn": _flash_churn,
    "markov_bw": _markov_bw,
    "outage_collapse": _outage_collapse,
}


def compile_scenario(name: str, sys: SystemConfig, sim: SimConfig,
                     n_rounds: int | None = None,
                     seed: int = 0) -> ScenarioTrace:
    """Compile a named scenario into per-round arrays for one run shape."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(SCENARIOS)}")
    rng = np.random.default_rng(seed)
    r = n_rounds or sim.n_rounds
    return SCENARIOS[name](r, sim.n_tasks, sim.n_edge_servers,
                           sim.n_cloud_servers, sys, rng)


def apply_scenario(stream: Observation, trace: ScenarioTrace) -> Observation:
    """Merge a compiled trace into a round-stacked stream.

    ``bw_mult`` composes multiplicatively with the stream's sampled
    fluctuation; ``u`` replaces the sampled realization (the scenario IS the
    adversary); availability / latency / budget fields attach directly.
    The ``none`` trace returns the stream unchanged (same object).
    """
    kw = {}
    if trace.bw_mult is not None:
        tm = jnp.asarray(trace.bw_mult, jnp.float32)
        kw["bw_mult"] = tm if stream.bw_mult is None else stream.bw_mult * tm
    if trace.u is not None:
        kw["u"] = jnp.asarray(trace.u, jnp.float32)
    for fld in ("tier_ok", "avail", "lat_mult", "bw_scale"):
        val = getattr(trace, fld)
        if val is not None:
            kw[fld] = jnp.asarray(val, jnp.float32)
    if (trace.arrive_n is None) != (trace.depart is None):
        raise ValueError(
            f"scenario {trace.name!r} carries only one of arrive_n/depart; "
            f"a churn trace needs both")
    if trace.arrive_n is not None:
        kw["arrive_n"] = jnp.asarray(trace.arrive_n, jnp.int32)
        kw["depart"] = jnp.asarray(trace.depart, bool)
    if not kw:
        return stream
    return dataclasses.replace(stream, **kw)


# ---------------------------------------------------------------------------
# metrics + suite runner
# ---------------------------------------------------------------------------
def scenario_metrics(mets, stream: Observation,
                     trace: ScenarioTrace) -> Dict[str, float]:
    """Scalar robustness metrics from one degraded run's (R, M) outputs.

    * ``cost`` / ``delay`` / ``accuracy``: run means (deterministic — no
      observation noise, so goldens are exact).
    * ``sla_violation_rate``: fraction of (round, task) realizations whose
      deterministic accuracy missed the requirement.
    * ``sla_cost``: ``cost + SLA_PENALTY * sla_violation_rate`` — the
      comparison metric.  A violated segment is re-served at high fidelity
      (the :data:`SLA_PENALTY` premium); raw cost alone would score an
      under-provisioned policy as "cheap" for shipping accuracy misses.
    * ``recovery_rounds``: rounds after ``trace.onset`` until the per-round
      mean cost first returns within 1.1x of the pre-onset mean (R - onset
      if it never does; 0 for always-on / benign scenarios).

    Churn runs (an ``alive`` mask in ``mets``) aggregate over alive lanes
    only — dead slots are zeroed by the masked realization and would
    otherwise dilute every mean by the vacancy rate — and report three
    extra scalars: ``mean_alive`` (pool occupancy), ``max_queue_depth``
    and ``dropped`` (admission backpressure).
    """
    acc = np.asarray(mets["accuracy"])
    aq = np.asarray(stream.aq)
    extra = {}
    if "alive" in mets:
        w = np.asarray(mets["alive"]).astype(np.float64)      # (R, M)
        n_r = np.maximum(w.sum(axis=1), 1.0)
        n_tot = max(w.sum(), 1.0)
        cost_r = np.asarray(mets["cost"]).sum(axis=1) / n_r   # (R,)
        viol = float(((acc < aq) * w).sum() / n_tot)
        delay = float(np.asarray(mets["delay"]).sum() / n_tot)
        accuracy = float((acc * w).sum() / n_tot)
        cloud_frac = float((np.maximum(np.asarray(mets["route"]), 0)
                            * w).sum() / n_tot)
        extra = {
            "mean_alive": float(w.sum(axis=1).mean()),
            "max_queue_depth": float(np.asarray(
                mets["queue_depth"]).max()),
            "dropped": float(np.asarray(mets["dropped"]).sum()),
        }
    else:
        cost_r = np.asarray(mets["cost"]).mean(axis=1)        # (R,)
        viol = float((acc < aq).mean())
        delay = float(np.asarray(mets["delay"]).mean())
        accuracy = float(acc.mean())
        cloud_frac = (float(np.asarray(mets["route"]).mean())
                      if "route" in mets else float("nan"))
    out = {
        "cost": float(cost_r.mean()),
        "delay": delay,
        "accuracy": accuracy,
        "sla_violation_rate": viol,
        "sla_cost": float(cost_r.mean()) + SLA_PENALTY * viol,
        "cloud_frac": cloud_frac,
        **extra,
    }
    r = cost_r.shape[0]
    onset = trace.onset
    if onset is None or onset <= 0 or onset >= r:
        out["recovery_rounds"] = 0.0
        return out
    pre = cost_r[:onset].mean()
    recovered = np.nonzero(cost_r[onset:] <= 1.1 * pre)[0]
    out["recovery_rounds"] = float(recovered[0] if recovered.size
                                   else r - onset)
    return out


def run_scenario(policy, scenario, *, streams: int = 64, rounds: int = 30,
                 seed: int = 11, scenario_seed: int = 0,
                 sys: SystemConfig | None = None, force: str | None = None,
                 return_mets: bool = False):
    """Serve one policy through one scenario: the canonical suite entry.

    ``policy``: a registry name (``make_policy``) or a built Policy.
    ``scenario``: a registry name or a pre-compiled :class:`ScenarioTrace`.
    The whole degraded run executes as the session's single compiled scan;
    returns :func:`scenario_metrics` (plus the raw (R, M) metrics when
    ``return_mets``).
    """
    from repro.serving.session import ServeSession

    sys = sys or SystemConfig()
    simc = SimConfig(n_tasks=streams, n_rounds=rounds, seed=seed,
                     bw_fluctuation=0.2)
    simulator = Simulator(sys, simc)
    stream = simulator.sample_stream(rounds)
    trace = (scenario if isinstance(scenario, ScenarioTrace)
             else compile_scenario(scenario, sys, simc, rounds,
                                   seed=scenario_seed))
    degraded = apply_scenario(stream, trace)
    if isinstance(policy, str):
        policy = make_policy(policy, sys)
    session = ServeSession(policy, streams, sim=simc, hedge=trace.hedge,
                           admission=trace.admission, force=force)
    mets = session.run(degraded)
    scalars = scenario_metrics(mets, degraded, trace)
    return (scalars, mets) if return_mets else scalars


def run_suite(policies=None, scenarios=None, *, streams: int = 64,
              rounds: int = 30, seed: int = 11, scenario_seed: int = 0,
              sys: SystemConfig | None = None,
              force: str | None = None) -> Dict[str, Dict[str, float]]:
    """Every policy x every scenario -> ``{"policy@scenario": metrics}``.

    The Table-2 generalization: robustness scalars per policy per adverse
    condition, each cell one compiled serve run.  Defaults cover the full
    registry against the full named suite.
    """
    from repro.serving.policy import POLICIES

    policies = sorted(POLICIES) if policies is None else list(policies)
    scenarios = list(SUITE) if scenarios is None else list(scenarios)
    rows = {}
    for s in scenarios:
        for p in policies:
            rows[f"{p}@{s}"] = run_scenario(
                p, s, streams=streams, rounds=rounds, seed=seed,
                scenario_seed=scenario_seed, sys=sys, force=force)
    return rows
