from repro.serving.simulator import SimConfig, Simulator  # noqa: F401
from repro.serving.baselines import BASELINES, make_method  # noqa: F401
