from repro.serving.simulator import SimConfig, Simulator, realize_rounds  # noqa: F401
from repro.serving.baselines import BASELINES, make_method  # noqa: F401
from repro.serving.policy import (  # noqa: F401
    Observation,
    POLICIES,
    Policy,
    make_policy,
)
from repro.serving.dispatch import (  # noqa: F401
    Completion,
    DispatchExecutor,
    PoolExecutor,
    Request,
    serve_serial_oracle,
)
from repro.serving.session import FinetuneConfig, ServeSession  # noqa: F401
from repro.serving.scan import run_scan, serve_scan  # noqa: F401
