from repro.serving.simulator import SimConfig, Simulator, realize_rounds  # noqa: F401
from repro.serving.baselines import BASELINES, make_method  # noqa: F401
from repro.serving.scan import run_scan, serve_scan  # noqa: F401
