"""Continuous-batching dispatch executor: token-level batching across tier
pools with measured feedback into the router.

The compiled router (``ServeSession.run``) emits per-round solutions; this
module is the layer that *executes* them on live :class:`ModelPool` tiers.
Routed segments become :class:`Request`\\ s (stream id, tier, fidelity-sized
token prompt, enqueue time) on per-pool queues, and each pool runs an
admit → prefill → decode scheduling loop:

* **bucketed prefill** — pending requests batch by exact prompt length
  (fidelity sizes are discrete, so buckets are too) with the batch axis
  padded to a power of two; one bucket admits per scheduling step.
* **token-level decode** — ONE decode step advances *every* in-flight
  segment of the pool against a fixed cache-slot slab with per-slot
  progress; segments join the decode batch the step after their prefill and
  leave the step they finish, their slot returning to the free pool.
* **interleave** — every scheduling step first admits (if slots are free
  and requests are pending) then decodes, so a long decode never starves
  new arrivals and a deep queue never starves resident segments.

Scheduling invariant (asserted in tests): the oldest pending request is
always part of the next admitted prefill bucket — bounded wait, no
length-class starvation.

The executor measures what the router's Stage-2 assumes it knows: per-tier
sojourn (wait + service) EWMAs and token throughput.  :meth:`feedback`
exposes them as a per-tier multiplier ``bw_mult = service / sojourn``
(clipped to ``[floor, 1]``) — 1.0 when the pool keeps up, shrinking as
queueing dominates — which ``ServeSession.apply_feedback`` folds into the
next round's :class:`Observation` (``bw_mult`` for realization, and its
capacity-weighted twin ``bw_scale`` for the C6 repair budget), closing the
router ↔ serving loop.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.straggler import p99_jnp


@dataclasses.dataclass
class Request:
    """One routed segment's token workload."""
    stream: int                 # stream / slot-lane id (router's task index)
    tier: int                   # 0 = edge, 1 = cloud
    tokens: np.ndarray          # (n_prefill,) int32 prompt
    decode_tokens: int = 8
    enqueue_t: float = 0.0      # stamped at submit when left 0


@dataclasses.dataclass
class Completion:
    """A finished request plus its measured lifecycle."""
    stream: int
    tier: int
    ids: np.ndarray             # (decode_tokens,) int32 decoded ids
    n_prefill: int
    enqueue_t: float
    admit_t: float
    finish_t: float

    @property
    def wait_s(self) -> float:
        return self.admit_t - self.enqueue_t

    @property
    def service_s(self) -> float:
        return self.finish_t - self.admit_t

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.enqueue_t

    @property
    def tokens(self) -> int:
        return self.n_prefill + len(self.ids)


@dataclasses.dataclass
class _Slot:
    req: Request
    admit_t: float
    ids: list               # decoded ids so far (first one from prefill)
    remaining: int          # decode steps still owed


def _bucket_pad(n: int, cap: int) -> int:
    """Smallest power of two >= n (capped) — bounds prefill recompiles."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class PoolExecutor:
    """The admit→prefill→decode loop for ONE tier pool.

    Owns the pool's pending queue, the fixed cache-slot slab, and the
    per-slot bookkeeping.  ``step()`` is one scheduling iteration; the
    multi-tier :class:`DispatchExecutor` round-robins it across pools.
    """

    def __init__(self, pool, *, n_slots: int = 16, max_prefill_len: int = 48,
                 max_prefill_batch: int = 8, clock=time.perf_counter):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.pool = pool
        self.n_slots = n_slots
        self.max_prefill_len = max_prefill_len
        self.max_prefill_batch = max_prefill_batch
        self.clock = clock
        self.pending: deque[Request] = deque()
        self.slab = pool.make_slab(n_slots, max_prefill_len)
        self.slots: list[Optional[_Slot]] = [None] * n_slots
        self.last_ids = np.zeros((n_slots,), np.int32)
        self.completions: list[Completion] = []
        # admission trace for the no-starvation invariant: one entry per
        # prefill bucket, (admitted stream ids, oldest-pending stream id)
        self.admission_log: list[tuple[list, int]] = []
        # sojourn EWMAs feeding DispatchExecutor.feedback()
        self.wait_ewma = 0.0
        self.service_ewma = 0.0
        self._ewma_n = 0

    def reset_measurements(self):
        """Forget completed-request measurements (EWMAs, completions, the
        admission trace) — e.g. after jit warmup — without touching the
        queue, the slab, or in-flight segments."""
        self.completions.clear()
        self.admission_log.clear()
        self.wait_ewma = 0.0
        self.service_ewma = 0.0
        self._ewma_n = 0

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request):
        n = int(np.asarray(req.tokens).shape[0])
        if n < 1 or n > self.max_prefill_len:
            raise ValueError(
                f"request prompt length {n} outside this executor's "
                f"1..{self.max_prefill_len} slab sizing")
        if req.decode_tokens < 1:
            raise ValueError("decode_tokens must be >= 1")
        if req.enqueue_t == 0.0:
            req.enqueue_t = self.clock()
        self.pending.append(req)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.pending and self.n_active == 0

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    # -- scheduling ---------------------------------------------------------
    def step(self) -> bool:
        """One scheduling iteration: admit one prefill bucket (if slots are
        free), then one token-level decode step over the slab.  Returns
        whether any work was done."""
        did = False
        free = self._free_slots()
        if self.pending and free:
            self._admit(free)
            did = True
        if self.n_active:
            self._decode_step()
            did = True
        return did

    def drain(self, max_steps: int | None = None):
        steps = 0
        while not self.idle:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return steps

    def _admit(self, free: list[int]):
        """Admit the oldest pending request's length bucket: FIFO scan
        collecting same-length requests (other lengths keep their queue
        position), one prefill, scatter into the free slots."""
        want = min(len(free), self.max_prefill_batch)
        head_len = int(np.asarray(self.pending[0].tokens).shape[0])
        batch, keep = [], deque()
        while self.pending and len(batch) < want:
            req = self.pending.popleft()
            if int(np.asarray(req.tokens).shape[0]) == head_len:
                batch.append(req)
            else:
                keep.append(req)
        keep.extend(self.pending)
        self.pending = keep
        oldest = batch[0].stream
        slots = free[:len(batch)]

        b_pad = _bucket_pad(len(batch), self.max_prefill_batch)
        toks = np.zeros((b_pad, head_len), np.int32)
        for i, req in enumerate(batch):
            toks[i] = np.asarray(req.tokens, np.int32)
        ids, cache = self.pool.prefill_batch(jnp.asarray(toks))
        self.slab = self.pool.insert_slab(self.slab, cache, slots)
        ids = np.asarray(ids)
        now = self.clock()
        for i, (req, slot) in enumerate(zip(batch, slots)):
            first = int(ids[i])
            self.last_ids[slot] = first
            self.slots[slot] = _Slot(req=req, admit_t=now, ids=[first],
                                     remaining=req.decode_tokens - 1)
        self.admission_log.append(([r.stream for r in batch], oldest))
        # decode_tokens=1 segments are done at prefill (serial parity:
        # serve_segment's decode loop runs zero iterations)
        self._retire_finished(now)

    def _decode_step(self):
        """Advance every resident segment by one token; retire finishers."""
        ids, self.slab = self.pool.decode_slab(self.slab, self.last_ids)
        ids = np.asarray(ids)
        now = self.clock()
        for slot, st in enumerate(self.slots):
            if st is None or st.remaining == 0:
                continue
            tok = int(ids[slot])
            st.ids.append(tok)
            st.remaining -= 1
            self.last_ids[slot] = tok
        self._retire_finished(now)

    def _retire_finished(self, now: float):
        for slot, st in enumerate(self.slots):
            if st is None or st.remaining > 0:
                continue
            req = st.req
            comp = Completion(
                stream=req.stream, tier=req.tier,
                ids=np.asarray(st.ids, np.int32),
                n_prefill=int(np.asarray(req.tokens).shape[0]),
                enqueue_t=req.enqueue_t, admit_t=st.admit_t, finish_t=now)
            self.completions.append(comp)
            self.slots[slot] = None
            stats = self.pool.stats
            stats.requests += 1
            stats.tokens += comp.tokens
            stats.latencies.append(comp.latency_s)
            a = 2.0 / (self._ewma_n + 2)    # warmup-weighted EWMA
            self.wait_ewma += a * (comp.wait_s - self.wait_ewma)
            self.service_ewma += a * (comp.service_s - self.service_ewma)
            self._ewma_n += 1


class DispatchExecutor:
    """Continuous-batching executor over ALL tier pools.

    ``step()`` round-robins one scheduling iteration across the tiers so no
    pool serializes behind another; ``serve(requests)`` is the submit+drain
    convenience the session's ``dispatch`` shim calls.
    """

    def __init__(self, pools: dict, *, n_slots: int = 16,
                 max_prefill_len: int = 48, max_prefill_batch: int = 8,
                 feedback_floor: float = 0.25, clock=time.perf_counter):
        if not 0.0 < feedback_floor <= 1.0:
            raise ValueError(f"feedback_floor must be in (0, 1], "
                             f"got {feedback_floor}")
        self.pools = pools
        self.feedback_floor = feedback_floor
        self.execs = {
            tier: PoolExecutor(pool, n_slots=n_slots,
                               max_prefill_len=max_prefill_len,
                               max_prefill_batch=max_prefill_batch,
                               clock=clock)
            for tier, pool in pools.items()
        }

    def submit(self, requests):
        for req in requests:
            if req.tier not in self.execs:
                raise ValueError(
                    f"request for stream {req.stream} targets unknown tier "
                    f"{req.tier}; pools serve {sorted(self.execs)}")
            self.execs[req.tier].submit(req)

    @property
    def idle(self) -> bool:
        return all(ex.idle for ex in self.execs.values())

    def reset_measurements(self):
        for ex in self.execs.values():
            ex.reset_measurements()

    def step(self) -> bool:
        did = False
        for ex in self.execs.values():
            did |= ex.step()
        return did

    def drain(self, max_steps: int | None = None):
        steps = 0
        while not self.idle:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return steps

    def serve(self, requests) -> dict:
        """Submit + drain, returning the per-tier stats of THIS request set
        (completions recorded since the call began)."""
        marks = {t: len(ex.completions) for t, ex in self.execs.items()}
        self.submit(requests)
        self.drain()
        return {t: self._tier_stats(t, since=marks[t])
                for t in self.execs
                if len(self.execs[t].completions) > marks[t]}

    # -- measurement --------------------------------------------------------
    def _tier_stats(self, tier: int, since: int = 0) -> dict:
        comps = self.execs[tier].completions[since:]
        if not comps:
            return {"requests": 0, "tokens": 0}
        lat = jnp.asarray([c.latency_s for c in comps], jnp.float32)
        span = (max(c.finish_t for c in comps)
                - min(c.enqueue_t for c in comps))
        toks = sum(c.tokens for c in comps)
        return {
            "requests": len(comps),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "p50_s": float(jnp.quantile(lat, 0.5)),
            "p99_s": float(p99_jnp(lat)),
            "mean_wait_s": float(np.mean([c.wait_s for c in comps])),
            "mean_service_s": float(np.mean([c.service_s for c in comps])),
        }

    def stats(self) -> dict:
        return {t: self._tier_stats(t) for t in self.execs}

    def feedback(self) -> dict:
        """Measured per-tier serving state for the router's next round.

        ``bw_mult[t] = clip(service / (service + wait), floor, 1)`` — the
        EWMA fraction of a request's sojourn spent actually being served.
        An unloaded pool reports 1.0 (the observation passes through
        unchanged); a pool whose queue dominates shrinks toward ``floor``,
        telling the router that tier's effective capacity is lower than
        nominal.  Tiers that never completed a request report 1.0 (no
        evidence, no adjustment).
        """
        tiers = sorted(self.execs)
        mult = np.ones((max(tiers) + 1,), np.float32) if tiers else \
            np.ones((2,), np.float32)
        per_tier = {}
        for t in tiers:
            ex = self.execs[t]
            if ex._ewma_n:
                sojourn = ex.service_ewma + ex.wait_ewma
                m = ex.service_ewma / max(sojourn, 1e-9)
                mult[t] = np.clip(m, self.feedback_floor, 1.0)
            per_tier[t] = {
                "bw_mult": float(mult[t]),
                "wait_ewma_s": ex.wait_ewma,
                "service_ewma_s": ex.service_ewma,
                "tokens_per_s": ex.pool.stats.tokens_per_s,
                "queue_depth": len(ex.pending),
                "in_flight": ex.n_active,
            }
        return {"bw_mult": mult, "per_tier": per_tier}


def serve_serial_oracle(pools: dict, requests, decode_tokens: int | None = None):
    """The serial reference execution of a request set: per tier, per prompt
    length, one :meth:`ModelPool.serve_segment` call in arrival order — no
    queueing, no interleave, no cross-batch token-level merge.  Returns
    {(stream) -> (decode ids)} so tests can assert the executor's outputs
    request-for-request, and the dispatch bench can measure the speedup
    against the exact same workload.
    """
    out = {}
    by_group: dict[tuple, list] = {}
    for req in requests:
        n = int(np.asarray(req.tokens).shape[0])
        by_group.setdefault((req.tier, n), []).append(req)
    for (tier, n), reqs in by_group.items():
        toks = jnp.asarray(np.stack([np.asarray(r.tokens, np.int32)
                                     for r in reqs]))
        dt = decode_tokens if decode_tokens is not None \
            else reqs[0].decode_tokens
        ids = np.asarray(pools[tier].serve_segment(toks, decode_tokens=dt))
        for i, r in enumerate(reqs):
            out[r.stream] = ids[i]
    return out
