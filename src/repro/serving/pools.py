"""Model pools: the edge/cloud tiers as live JAX serving endpoints.

A pool owns one model variant (params + jit'd prefill/decode) and a request
queue; the R2E-VID router's (route, v) decision maps a segment's token
workload to a pool.  At production scale each pool is a TP slice of the
fleet; here pools run reduced variants on the host mesh so examples/tests
exercise the real code path end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import Ctx, cache_specs, decode_step, model_specs, prefill
from repro.models.config import ModelConfig
from repro.models.params import init_params


@dataclasses.dataclass
class PoolStats:
    requests: int = 0
    tokens: int = 0
    busy_s: float = 0.0


class ModelPool:
    def __init__(self, cfg: ModelConfig, rng=None, name: str = "pool"):
        self.cfg = cfg
        self.name = name
        self.ctx = Ctx(cfg=cfg)
        if rng is None:
            rng = jax.random.PRNGKey(0)
        self.params = init_params(model_specs(cfg), rng)
        self._prefill = jax.jit(lambda p, b: prefill(self.ctx, p, b))
        self._decode = jax.jit(lambda p, c, b: decode_step(self.ctx, p, c, b))
        self.stats = PoolStats()

    def serve_segment(self, tokens, decode_tokens: int = 8):
        """Prefill a token batch then decode a few tokens; returns text ids."""
        t0 = time.perf_counter()
        b, s = tokens.shape
        if b == 0:
            # a fully-drained tier (every routed lane dead/elsewhere) is a
            # legal dispatch, not a crash — serve nothing, touch no stats
            return jnp.zeros((0, decode_tokens), jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        out = [jnp.argmax(logits, axis=-1)]
        for _ in range(decode_tokens - 1):
            logits, cache = self._decode(self.params, cache, {"tokens": out[-1][:, None]})
            out.append(jnp.argmax(logits, axis=-1))
        jax.block_until_ready(out[-1])
        self.stats.requests += b
        self.stats.tokens += b * (s + decode_tokens)
        self.stats.busy_s += time.perf_counter() - t0
        return jnp.stack(out, axis=1)


def make_tier_pools(edge_cfg: ModelConfig, cloud_cfg: ModelConfig):
    return {
        0: ModelPool(edge_cfg, jax.random.PRNGKey(1), name="edge"),
        1: ModelPool(cloud_cfg, jax.random.PRNGKey(2), name="cloud"),
    }
