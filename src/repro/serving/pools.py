"""Model pools: the edge/cloud tiers as live JAX serving endpoints.

A pool owns one model variant (params + jit'd prefill/decode) and a request
queue; the R2E-VID router's (route, v) decision maps a segment's token
workload to a pool.  At production scale each pool is a TP slice of the
fleet; here pools run reduced variants on the host mesh so examples/tests
exercise the real code path end-to-end.

Two serving surfaces:

* :meth:`ModelPool.serve_segment` — the original serial path (one prefill +
  an eager decode loop per batch); retained as the parity oracle for the
  continuous-batching executor.
* the **cache-slot slab** entry points (:meth:`make_slab`,
  :meth:`prefill_batch`, :meth:`insert_slab`, :meth:`decode_slab`) — the
  building blocks :mod:`repro.serving.dispatch` schedules: a fixed slab of
  ``n_slots`` KV-cache rows with *per-slot* progress (the model's decode
  path accepts a ``(B,)`` length vector), so concurrent segments join and
  leave the decode batch between steps and cache slots are reused in place.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Ctx, cache_specs, decode_step, model_specs, prefill
from repro.models.config import ModelConfig
from repro.models.params import init_params, tree_map_specs
from repro.runtime.straggler import p99_jnp


@dataclasses.dataclass
class PoolStats:
    """Counters plus per-request latency samples.

    ``latencies`` holds one sojourn sample (seconds, enqueue→finish; the
    serial path has no queue so its samples are batch wall time) per served
    request; the derived quantiles reuse the straggler toolkit's
    ``p99_jnp`` so serving and realization report tails the same way.
    """
    requests: int = 0
    tokens: int = 0
    busy_s: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.busy_s, 1e-9)

    def p50_s(self) -> float:
        if not self.latencies:
            return 0.0
        return float(jnp.quantile(
            jnp.asarray(self.latencies, jnp.float32), 0.5))

    def p99_s(self) -> float:
        if not self.latencies:
            return 0.0
        return float(p99_jnp(self.latencies))

    def summary(self) -> dict:
        return {
            "requests": self.requests,
            "tokens": self.tokens,
            "busy_s": self.busy_s,
            "tokens_per_s": self.tokens_per_s,
            "p50_s": self.p50_s(),
            "p99_s": self.p99_s(),
        }


# ---------------------------------------------------------------------------
# Slab primitives (module-level jit so they are shared per (ctx, shapes))
# ---------------------------------------------------------------------------
def _insert_slab_impl(slab, cache, slots):
    """Scatter a prefilled cache's first ``len(slots)`` rows into slab slots.

    Cache leaves under ``segments`` carry the stacked layer axis in front,
    so the request/batch axis is axis 1; a prefill cache's seq axis may be
    shorter than the slab's (shorter prompts) and is zero-padded — padded
    entries sit beyond the slot's length and are masked by decode attention.
    """
    n_real = slots.shape[0]

    def put(sl, cl):
        cl = jax.lax.slice_in_dim(cl, 0, n_real, axis=1)
        pad = [(0, 0)] * cl.ndim
        for ax in range(2, cl.ndim):
            pad[ax] = (0, sl.shape[ax] - cl.shape[ax])
        return sl.at[:, slots].set(jnp.pad(cl, pad))

    segments = jax.tree_util.tree_map(put, slab["segments"],
                                      cache["segments"])
    length = slab["length"].at[slots].set(cache["length"])
    return {"length": length, "segments": segments}


_insert_slab = jax.jit(_insert_slab_impl, donate_argnums=(0,))


class ModelPool:
    def __init__(self, cfg: ModelConfig, rng=None, name: str = "pool"):
        self.cfg = cfg
        self.name = name
        self.ctx = Ctx(cfg=cfg)
        if rng is None:
            rng = jax.random.PRNGKey(0)
        self.params = init_params(model_specs(cfg), rng)
        self._prefill = jax.jit(lambda p, b: prefill(self.ctx, p, b))
        self._decode = jax.jit(lambda p, c, b: decode_step(self.ctx, p, c, b))
        self._decode_slab = jax.jit(self._decode_slab_impl,
                                    donate_argnums=(1,))
        self.stats = PoolStats()

    def serve_segment(self, tokens, decode_tokens: int = 8):
        """Prefill a token batch then decode a few tokens; returns text ids."""
        t0 = time.perf_counter()
        b, s = tokens.shape
        if b == 0:
            # a fully-drained tier (every routed lane dead/elsewhere) is a
            # legal dispatch, not a crash — serve nothing, touch no stats
            return jnp.zeros((0, decode_tokens), jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        out = [jnp.argmax(logits, axis=-1)]
        for _ in range(decode_tokens - 1):
            logits, cache = self._decode(self.params, cache, {"tokens": out[-1][:, None]})
            out.append(jnp.argmax(logits, axis=-1))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t0
        self.stats.requests += b
        self.stats.tokens += b * (s + decode_tokens)
        self.stats.busy_s += dt
        self.stats.latencies.extend([dt] * b)
        return jnp.stack(out, axis=1)

    # -- continuous-batching slab entry points ------------------------------
    def make_slab(self, n_slots: int, max_prefill_len: int):
        """A fixed slab of ``n_slots`` cache rows sized for prompts up to
        ``max_prefill_len`` tokens plus the model's decode headroom.  The
        scalar cache ``length`` becomes a ``(n_slots,)`` vector — per-slot
        progress, so rows at different depths co-batch in one decode step."""
        specs = cache_specs(self.cfg, n_slots, max_prefill_len)
        slab = tree_map_specs(lambda s: jnp.zeros(s.shape, s.dtype), specs)
        slab["length"] = jnp.zeros((n_slots,), jnp.int32)
        return slab

    def prefill_batch(self, tokens):
        """Prefill one bucketed-length batch.  Returns (first decoded ids
        (B,), the batch's fresh cache) — the ids are the segment's first
        output token, exactly as in :meth:`serve_segment`."""
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        ids = jnp.argmax(logits, axis=-1)
        jax.block_until_ready(ids)
        self.stats.busy_s += time.perf_counter() - t0
        return ids, cache

    def insert_slab(self, slab, cache, slots):
        """Scatter ``cache``'s first ``len(slots)`` rows into ``slab`` at
        the given slot indices (donating the slab buffers).  Rows beyond
        ``len(slots)`` are bucket padding and are dropped."""
        return _insert_slab(slab, cache, jnp.asarray(slots, jnp.int32))

    def _decode_slab_impl(self, params, slab, last_ids):
        logits, slab = decode_step(self.ctx, params, slab,
                                   {"tokens": last_ids[:, None]})
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), slab

    def decode_slab(self, slab, last_ids):
        """One token-level decode step over the WHOLE slab: every slot
        advances by one token against its own cache progress.  Returns
        ((n_slots,) next ids, the updated slab).  Inactive slots compute
        garbage that the executor ignores — the fixed shape is what keeps
        this a single compiled program."""
        t0 = time.perf_counter()
        ids, slab = self._decode_slab(self.params, slab,
                                      jnp.asarray(last_ids, jnp.int32))
        jax.block_until_ready(ids)
        self.stats.busy_s += time.perf_counter() - t0
        return ids, slab


def make_tier_pools(edge_cfg: ModelConfig, cloud_cfg: ModelConfig):
    return {
        0: ModelPool(edge_cfg, jax.random.PRNGKey(1), name="edge"),
        1: ModelPool(cloud_cfg, jax.random.PRNGKey(2), name="cloud"),
    }
