"""Deprecation shims: the pre-PR-5 whole-run serving entry points, rebuilt on
:class:`~repro.serving.session.ServeSession`.

``serve_scan`` / ``run_scan`` keep their original signatures and outputs —
the session's compiled scan lowers the exact same gate → Stage-1 → CCG → C6
→ realization round body, so decisions and metrics stay bit-identical to the
pre-refactor drivers (parity-locked against fixed-seed goldens in
tests/test_session.py).  New code should construct the policy + session
directly:

    policy = make_policy("r2evid", sys, gate_cfg=gcfg, gate_params=gp)
    session = ServeSession(policy, n_streams=M)
    mets = session.run(stream)          # stream: round-stacked Observation

which also serves every baseline through the same compiled driver.
"""
from __future__ import annotations

import numpy as np

from repro.core.gating import GateConfig
from repro.core.robust import RobustProblem
from repro.core.router import RouterConfig, RouterState
from repro.serving.policy import Observation, R2EVidPolicy
from repro.serving.session import ServeSession
from repro.serving.simulator import Simulator


def serve_scan(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx_seq,               # (R, M, d) per-round segment features
    z_seq,                # (R, M) content difficulty
    aq_seq,               # (R, M) accuracy requirements
    bw_mult_seq,          # (R, 2) per-tier bandwidth fluctuation
    u_seq,                # (R, K) realized compute deviation
    rcfg: RouterConfig = RouterConfig(),
    n_edge: int = 4,
    n_cloud: int = 1,
    mesh=None,
    mesh_axis: str = "data",
):
    """Route and realize R rounds in one ``lax.scan`` (deprecation shim).

    Returns ``(final_state, mets)`` exactly like the pre-PR-5 driver:
    ``mets`` holds (R, M) deterministic delay / energy / cost / accuracy
    plus the decisions (route, r, p, v) and gate scores tau; observation
    noise stays the caller's job.  ``state`` is donated on the dense path;
    with a ``mesh`` the whole round body is shard_mapped over the stream
    axis (padded to any device count) with identical metrics.
    """
    policy = R2EVidPolicy(prob=prob, gate_params=gate_params,
                          gate_cfg=gate_cfg, rcfg=rcfg)
    session = ServeSession(policy, n_streams=dx_seq.shape[1],
                           n_edge=n_edge, n_cloud=n_cloud, state=state)
    stream = Observation(z=z_seq, aq=aq_seq, dx=dx_seq,
                         bw_mult=bw_mult_seq, u=u_seq)
    mets = session.run(stream, mesh=mesh, mesh_axis=mesh_axis)
    return session.state, mets


def run_scan(
    sim: Simulator,
    gate_cfg: GateConfig,
    gate_params,
    dx_seq=None,
    n_rounds: int | None = None,
    rcfg: RouterConfig = RouterConfig(),
    feature_seed: int = 0,
    mesh=None,
):
    """Host wrapper (deprecation shim): sample rounds, run the compiled
    session, aggregate the same scalar metric dict as ``Simulator.run``.

    Round sampling, feature synthesis, and the one-shot observation-noise
    draw keep the pre-PR-5 rng order, so outputs are unchanged.
    """
    stream = sim.sample_stream(n_rounds, dx_seq, feature_seed)
    policy = R2EVidPolicy(prob=RobustProblem.build(sim.sys),
                          gate_params=gate_params, gate_cfg=gate_cfg,
                          rcfg=rcfg)
    session = ServeSession(policy, n_streams=sim.sim.n_tasks, sim=sim.sim,
                           mesh=mesh)
    mets = session.run(stream)
    return sim.aggregate(mets, np.asarray(stream.aq))
