"""Whole-run serving engine: gate → Stage-1 → CCG → C6 → realization under
one ``lax.scan``.

``run_batch`` still drives rounds from a Python loop because methods are
stateful host callables.  The R2E-VID engine, however, is a pure jit-compiled
step (``route_step``), and the deterministic realization path is pure jnp
(``realize_rounds``) — so the *entire* multi-round serving run compiles to a
single program: ``RouterState`` is the carry, each scan step routes one
segment batch and realizes its round, and the host touches the run exactly
twice (feed inputs, read stacked metrics).

``serve_scan`` is the compiled driver; ``run_scan`` is the host wrapper that
samples rounds from a :class:`Simulator`, applies observation noise exactly
like ``run_batch`` does, and aggregates the same scalar metrics — metric
parity between the two is covered by tests/test_engine_scan.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import feature_dim
from repro.core.gating import GateConfig
from repro.core.robust import RobustProblem
from repro.core.router import RouterConfig, RouterState, init_router_state, route_step
from repro.serving.simulator import Simulator, realize_rounds


@partial(jax.jit, static_argnames=("gate_cfg", "rcfg", "n_edge", "n_cloud"))
def serve_scan(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx_seq,               # (R, M, d) per-round segment features
    z_seq,                # (R, M) content difficulty
    aq_seq,               # (R, M) accuracy requirements
    bw_mult_seq,          # (R, 2) per-tier bandwidth fluctuation
    u_seq,                # (R, K) realized compute deviation
    rcfg: RouterConfig = RouterConfig(),
    n_edge: int = 4,
    n_cloud: int = 1,
):
    """Route and realize R rounds in one ``lax.scan``.

    Returns ``(final_state, mets)`` where ``mets`` holds (R, M) arrays:
    deterministic delay / energy / cost / accuracy plus the decisions
    (route, r, p, v) and the gate scores tau.  Observation noise is the
    caller's job (it needs host rng state), matching ``realize_batch``.
    """
    sys = prob.lat.sys

    def body(st, xs):
        dx, z, aq, bwm, u = xs
        st, sol = route_step(prob, gate_cfg, gate_params, st, dx, z, aq, rcfg=rcfg)
        met = realize_rounds(
            sys, z, bwm, u, sol["route"], sol["r"], sol["p"], sol["v"],
            n_edge=n_edge, n_cloud=n_cloud,
        )
        out = {k: met[k] for k in ("delay", "energy", "cost", "accuracy")}
        out.update({k: sol[k] for k in ("route", "r", "p", "v", "tau")})
        return st, out

    return jax.lax.scan(
        body, state, (dx_seq, z_seq, aq_seq, bw_mult_seq, u_seq)
    )


def run_scan(
    sim: Simulator,
    gate_cfg: GateConfig,
    gate_params,
    dx_seq=None,
    n_rounds: int | None = None,
    rcfg: RouterConfig = RouterConfig(),
    feature_seed: int = 0,
):
    """Host wrapper: sample rounds, run ``serve_scan``, aggregate metrics.

    Mirrors ``Simulator.run_batch`` driven by a :class:`RouterEngine` method:
    rounds are sampled first (same rng order), the compiled scan routes and
    realizes them, then observation noise is drawn in one shot exactly like
    ``realize_batch``.  Returns the same scalar metric dict as ``run_batch``.
    """
    n = n_rounds or sim.sim.n_rounds
    m = sim.sim.n_tasks
    rnds = [sim.sample_round() for _ in range(n)]
    if dx_seq is None:
        frng = np.random.default_rng(feature_seed)
        dx_seq = jnp.asarray(
            frng.normal(size=(n, m, feature_dim())), jnp.float32)

    prob = RobustProblem.build(sim.sys)
    state = init_router_state(gate_cfg, m)
    _, mets = serve_scan(
        prob, gate_cfg, gate_params, state,
        dx_seq,
        jnp.asarray(np.stack([rd["z"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["aq"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["bw_mult"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["u"] for rd in rnds]), jnp.float32),
        rcfg=rcfg,
        n_edge=sim.sim.n_edge_servers, n_cloud=sim.sim.n_cloud_servers,
    )
    aq = np.stack([rd["aq"] for rd in rnds])
    acc, success = sim.observe(np.asarray(mets["accuracy"]), aq)
    out = {k: float(np.asarray(mets[k]).mean(axis=1).mean())
           for k in ("delay", "energy", "cost")}
    out["accuracy"] = float(acc.mean(axis=1).mean())
    out["success"] = float(success.mean(axis=1).mean())
    out["cloud_frac"] = float(np.asarray(mets["route"]).mean(axis=1).mean())
    return out
