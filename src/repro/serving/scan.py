"""Whole-run serving engine: gate → Stage-1 → CCG → C6 → realization under
one ``lax.scan`` — optionally shard_mapped over the stream axis.

``run_batch`` still drives rounds from a Python loop because methods are
stateful host callables.  The R2E-VID engine, however, is a pure jit-compiled
step (``route_step``), and the deterministic realization path is pure jnp
(``realize_rounds``) — so the *entire* multi-round serving run compiles to a
single program: ``RouterState`` is the carry, each scan step routes one
segment batch and realizes its round, and the host touches the run exactly
twice (feed inputs, read stacked metrics).

``serve_scan`` is the compiled driver.  With a ``mesh`` it becomes ONE
compiled *sharded* scan: the per-stream work (batched gate, Stage-1, the
unrolled CCG, temporal consistency) runs on each device's local stream shard,
then the decisions are all-gathered so the cross-task tail of the round (C6
bandwidth repair, LPT realization) is computed on the exact real-M batch —
replicated arithmetic, so multi-device metrics are identical to the
single-device path, and M pads to any device count.  ``run_scan`` is the host
wrapper that samples rounds from a :class:`Simulator`, applies observation
noise exactly like ``run_batch`` does, and aggregates the same scalar
metrics — metric parity between the paths is covered by
tests/test_engine_scan.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import feature_dim
from repro.core.gating import GateConfig
from repro.core.robust import RobustProblem
from repro.core.router import (
    RouterConfig,
    RouterState,
    enforce_bandwidth,
    init_router_state,
    route_segment,
    route_step,
)
from repro.serving.simulator import Simulator, realize_rounds

_MET_KEYS = ("delay", "energy", "cost", "accuracy")
_SOL_KEYS = ("route", "r", "p", "v", "tau")


def serve_scan(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx_seq,               # (R, M, d) per-round segment features
    z_seq,                # (R, M) content difficulty
    aq_seq,               # (R, M) accuracy requirements
    bw_mult_seq,          # (R, 2) per-tier bandwidth fluctuation
    u_seq,                # (R, K) realized compute deviation
    rcfg: RouterConfig = RouterConfig(),
    n_edge: int = 4,
    n_cloud: int = 1,
    mesh=None,
    mesh_axis: str = "data",
):
    """Route and realize R rounds in one ``lax.scan``.

    Returns ``(final_state, mets)`` where ``mets`` holds (R, M) arrays:
    deterministic delay / energy / cost / accuracy plus the decisions
    (route, r, p, v) and the gate scores tau.  Observation noise is the
    caller's job (it needs host rng state), matching ``realize_batch``.

    ``mesh``: optional — when given, the whole round body is shard_mapped
    over ``mesh_axis`` (the stream/task axis M, padded to any device count)
    and the run compiles to a single sharded program; metrics and the final
    state are identical to the unsharded path.  Without a mesh, ``state`` is
    donated (the carry is threaded, not copied).
    """
    if mesh is None:
        return _serve_scan_dense(
            prob, gate_cfg, gate_params, state, dx_seq, z_seq, aq_seq,
            bw_mult_seq, u_seq, rcfg=rcfg, n_edge=n_edge, n_cloud=n_cloud)
    return _serve_scan_sharded(
        prob, gate_cfg, gate_params, state, dx_seq, z_seq, aq_seq,
        bw_mult_seq, u_seq, rcfg=rcfg, n_edge=n_edge, n_cloud=n_cloud,
        mesh=mesh, mesh_axis=mesh_axis)


@partial(jax.jit, static_argnames=("gate_cfg", "rcfg", "n_edge", "n_cloud"),
         donate_argnames=("state",))
def _serve_scan_dense(
    prob, gate_cfg, gate_params, state, dx_seq, z_seq, aq_seq,
    bw_mult_seq, u_seq, rcfg: RouterConfig, n_edge: int, n_cloud: int,
):
    sys = prob.lat.sys

    def body(st, xs):
        dx, z, aq, bwm, u = xs
        st, sol = route_step(prob, gate_cfg, gate_params, st, dx, z, aq, rcfg=rcfg)
        met = realize_rounds(
            sys, z, bwm, u, sol["route"], sol["r"], sol["p"], sol["v"],
            n_edge=n_edge, n_cloud=n_cloud,
        )
        out = {k: met[k] for k in _MET_KEYS}
        out.update({k: sol[k] for k in _SOL_KEYS})
        return st, out

    return jax.lax.scan(
        body, state, (dx_seq, z_seq, aq_seq, bw_mult_seq, u_seq)
    )


@partial(jax.jit, static_argnames=("gate_cfg", "rcfg", "n_edge", "n_cloud",
                                   "mesh", "mesh_axis"))
def _serve_scan_sharded(
    prob, gate_cfg, gate_params, state, dx_seq, z_seq, aq_seq,
    bw_mult_seq, u_seq, rcfg: RouterConfig, n_edge: int, n_cloud: int,
    mesh, mesh_axis: str,
):
    """One compiled sharded scan over the whole run.

    Per-stream stages run on each device's local shard of M; the cheap
    cross-task tail (C6 repair + realization, O(M log M)) runs on the
    all-gathered real-M batch — replicated, hence bit-comparable to the
    dense path — and the repaired routes are sliced back into the local
    carry.  The stream axis is padded to a multiple of the device count
    with dummy streams (no history, zero features) that are dropped from
    every gathered computation, so any M works on any mesh.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import pad_leading, shard_map

    sys = prob.lat.sys          # static config — safe to close over
    m = dx_seq.shape[1]
    n_dev = mesh.shape[mesh_axis]
    pad = (-m) % n_dev
    local_m = (m + pad) // n_dev

    pad_streams = lambda x: jnp.moveaxis(
        pad_leading(jnp.moveaxis(x, 1, 0), pad), 0, 1)
    dx_seq, z_seq, aq_seq = map(pad_streams, (dx_seq, z_seq, aq_seq))
    state = RouterState(
        prev_route=pad_leading(state.prev_route, pad, value=-1),
        prev_tau=pad_leading(state.prev_tau, pad),
        gate=jax.tree_util.tree_map(lambda x: pad_leading(x, pad), state.gate),
    )

    def shard_body(pb, gp, st_l, dx_l, z_l, aq_l, bwm_seq, u_seq_):
        lat = pb.lat

        def body(st, xs):
            dx, z, aq, bwm, u = xs
            new_gate, taus, sol = route_segment(
                pb, gate_cfg, gp, st, dx, z, aq, rcfg)
            # cross-task tail on the gathered REAL batch (padding dropped):
            # identical arithmetic to the dense path on every device
            gather = lambda x: jax.lax.all_gather(
                x, mesh_axis, axis=0, tiled=True)[:m]
            z_g, aq_g = gather(z), gather(aq)
            sol_g = {k: gather(v) for k, v in sol.items()}
            sol_g, _ = enforce_bandwidth(lat, sol_g, z_g, aq_g,
                                         rounds=rcfg.repair_rounds)
            met = realize_rounds(
                sys, z_g, bwm, u, sol_g["route"], sol_g["r"], sol_g["p"],
                sol_g["v"], n_edge=n_edge, n_cloud=n_cloud,
            )
            out = {k: met[k] for k in _MET_KEYS}
            out.update({k: sol_g[k] for k in _SOL_KEYS})
            # slice this device's shard of the repaired routes back into the
            # carry (dummy streams keep the no-history marker)
            route_pad = pad_leading(sol_g["route"].astype(jnp.int32), pad, value=-1)
            start = jax.lax.axis_index(mesh_axis) * local_m
            st = RouterState(
                prev_route=jax.lax.dynamic_slice_in_dim(route_pad, start, local_m),
                prev_tau=taus.astype(jnp.float32),
                gate=new_gate,
            )
            return st, out

        return jax.lax.scan(body, st_l, (dx_l, z_l, aq_l, bwm_seq, u_seq_))

    final_state, mets = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), P(), P(mesh_axis), P(None, mesh_axis),
                  P(None, mesh_axis), P(None, mesh_axis), P(), P()),
        out_specs=(P(mesh_axis), P()), check_vma=False,
    )(prob, gate_params, state, dx_seq, z_seq, aq_seq, bw_mult_seq, u_seq)
    final_state = jax.tree_util.tree_map(lambda x: x[:m], final_state)
    return final_state, mets


def run_scan(
    sim: Simulator,
    gate_cfg: GateConfig,
    gate_params,
    dx_seq=None,
    n_rounds: int | None = None,
    rcfg: RouterConfig = RouterConfig(),
    feature_seed: int = 0,
    mesh=None,
):
    """Host wrapper: sample rounds, run ``serve_scan``, aggregate metrics.

    Mirrors ``Simulator.run_batch`` driven by a :class:`RouterEngine` method:
    rounds are sampled first (same rng order), the compiled scan routes and
    realizes them, then observation noise is drawn in one shot exactly like
    ``realize_batch``.  Returns the same scalar metric dict as ``run_batch``.
    ``mesh`` forwards to ``serve_scan`` (sharded whole-run scan).
    """
    n = n_rounds or sim.sim.n_rounds
    m = sim.sim.n_tasks
    rnds = [sim.sample_round() for _ in range(n)]
    if dx_seq is None:
        frng = np.random.default_rng(feature_seed)
        dx_seq = jnp.asarray(
            frng.normal(size=(n, m, feature_dim())), jnp.float32)

    prob = RobustProblem.build(sim.sys)
    state = init_router_state(gate_cfg, m)
    _, mets = serve_scan(
        prob, gate_cfg, gate_params, state,
        dx_seq,
        jnp.asarray(np.stack([rd["z"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["aq"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["bw_mult"] for rd in rnds]), jnp.float32),
        jnp.asarray(np.stack([rd["u"] for rd in rnds]), jnp.float32),
        rcfg=rcfg,
        n_edge=sim.sim.n_edge_servers, n_cloud=sim.sim.n_cloud_servers,
        mesh=mesh,
    )
    aq = np.stack([rd["aq"] for rd in rnds])
    acc, success = sim.observe(np.asarray(mets["accuracy"]), aq)
    out = {k: float(np.asarray(mets[k]).mean(axis=1).mean())
           for k in ("delay", "energy", "cost")}
    out["accuracy"] = float(acc.mean(axis=1).mean())
    out["success"] = float(success.mean(axis=1).mean())
    out["cloud_frac"] = float(np.asarray(mets["route"]).mean(axis=1).mean())
    return out
