"""Baseline methods (paper §4.1.1) + the R2E-VID method adapter — retained
as the PARITY ORACLES for the compiled policies.

Since PR 5 the serving loop drives :mod:`repro.serving.policy` — pure jnp
``Policy.decide`` steps under the compiled ``ServeSession`` scan — and
``Simulator.run`` no longer accepts these host closures.  Each closure here
is kept verbatim as the decision-for-decision oracle its policy port is
tested against (tests/test_policy.py), exactly like ``solve_ccg_while``
oracles the unrolled CCG.

  A²     [Jiang+ RTSS'21] — cloud-only joint model-and-data adaptation:
         minimizes nominal cost over (r, p, v) with y ≡ cloud.
  JCAB   [Wang+ INFOCOM'20] — edge-cloud joint configuration adaptation and
         bandwidth allocation; nominal (non-robust), single mid model ladder
         position per tier unless infeasible.
  RDAP   [Su+ 2022] — prediction-based deployment: plans against an EMA
         difficulty forecast ẑ (stale under content shift), nominal cost.
  Sniper [Liu+ DAC'22] — similarity-aware scheduling: reuses the config of
         the most similar previously-profiled task (cheap, but drifts).
  R2EVID — ours: temporal gate warm-start + CCG robust selection +
         temporal-consistency constraint + C6 bandwidth repair.

Every method sees the same observables: (ẑ or z, A^q); none sees realized u.

All methods search the shared :class:`DecisionLattice` — the flat (F, K)
cost/feasibility layout and the (route, r, p) ↔ y index maps live there,
not here.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig
from repro.core.lattice import DecisionLattice
from repro.core.robust import RobustProblem, solve_ccg
from repro.core.router import enforce_bandwidth

BIG = 1e9


def _argmin_feasible(lat: DecisionLattice, z, aq, *, force_route=None,
                     allowed_versions=None, margin=None):
    """Vectorized nominal argmin over the decision lattice (host-side)."""
    sys = lat.sys
    m = len(z)
    if margin is None:
        margin = sys.acc_margin_nominal
    f_flat = np.asarray(lat.accuracy_flat(jnp.asarray(z)))        # (M, F, K)
    total = np.asarray(lat.c1_flat)[None, :, None] + np.asarray(lat.b2_flat)[None]
    feas = f_flat >= (np.asarray(aq) + margin)[:, None, None]
    if force_route is not None:
        y_route, _, _ = lat.unflatten_index(np.arange(lat.n_flat))
        feas = feas & (y_route == force_route)[None, :, None]
    if allowed_versions is not None:
        mv = np.zeros((1, 1, sys.num_versions), bool)
        mv[:, :, allowed_versions] = True
        feas = feas & mv
    obj = np.where(feas, np.broadcast_to(total, feas.shape), BIG)
    flat = obj.reshape(m, -1)
    idx = flat.argmin(axis=1)
    # fall back to max-accuracy config when nothing is feasible
    none_ok = flat[np.arange(m), idx] >= BIG
    if none_ok.any():
        acc_flat = f_flat.reshape(m, -1)
        idx[none_ok] = acc_flat[none_ok].argmax(axis=1)
    y = idx // sys.num_versions
    v = idx % sys.num_versions
    route, r, p = lat.unflatten_index(y)
    return {"route": route, "r": r, "p": p, "v": v}


# ---------------------------------------------------------------------------
def a2_cloud_only(sys: SystemConfig):
    lat = DecisionLattice.build(sys)

    def method(rnd, state):
        return _argmin_feasible(lat, rnd["z"], rnd["aq"], force_route=1)
    return method


def jcab(sys: SystemConfig):
    lat = DecisionLattice.build(sys)
    mid = sys.num_versions // 2

    def method(rnd, state):
        # joint config + bandwidth allocation, single mid-ladder model;
        # escalates version only when mid is infeasible everywhere
        cfg = _argmin_feasible(lat, rnd["z"], rnd["aq"], allowed_versions=[mid])
        f = np.asarray(lat.accuracy(jnp.asarray(rnd["z"])))
        ok = f[np.arange(len(rnd["z"])), cfg["r"], cfg["p"], cfg["v"], cfg["route"]] >= rnd["aq"]
        if (~ok).any():
            esc = _argmin_feasible(lat, rnd["z"][~ok], rnd["aq"][~ok])
            for k in cfg:
                cfg[k][~ok] = esc[k]
        return cfg
    return method


def rdap(sys: SystemConfig, ema: float = 0.7):
    lat = DecisionLattice.build(sys)

    def method(rnd, state):
        z_prev = state.get("z_ema")
        z_hat = rnd["z"] if z_prev is None else ema * z_prev + (1 - ema) * rnd["z"]
        # NOTE: plans against the *forecast*, reality uses rnd["z"]
        state["z_ema"] = rnd["z"].copy()
        return _argmin_feasible(lat, z_hat, rnd["aq"])
    return method


def sniper(sys: SystemConfig, n_profiles: int = 8):
    lat = DecisionLattice.build(sys)

    def method(rnd, state):
        profiles = state.get("profiles")  # (n, 2): z, aq -> config rows
        cfg = _argmin_feasible(lat, rnd["z"], rnd["aq"])
        if profiles is None:
            state["profiles"] = {
                "key": np.stack([rnd["z"], rnd["aq"]], 1)[:n_profiles],
                "cfg": {k: v[:n_profiles].copy() for k, v in cfg.items()},
            }
            return cfg
        # reuse most-similar profiled config (the similarity shortcut)
        key = np.stack([rnd["z"], rnd["aq"]], 1)
        d = ((key[:, None, :] - profiles["key"][None]) ** 2).sum(-1)
        nn = d.argmin(1)
        reused = {k: profiles["cfg"][k][nn] for k in cfg}
        # profile refresh for badly matched tasks
        far = d.min(1) > 0.02
        for k in cfg:
            reused[k][far] = cfg[k][far]
        return reused
    return method


def r2evid(sys: SystemConfig, gate_cfg=None, gate_params=None, use_gate: bool = True,
           use_stage1: bool = True, use_stage2: bool = True):
    """Ours.  Ablations (§4.4):
      use_stage1=False — no adaptive configuration/partitioning: static mid
        (r, p), edge-pinned route; only the robust version selection remains.
      use_stage2=False — no robust multi-model selection: Stage-1 adaptive
        config but a fixed mid-ladder version, nominal planning.
    """
    prob = RobustProblem.build(sys)
    lat = prob.lat

    def method(rnd, state):
        z = jnp.asarray(rnd["z"])
        aq = jnp.asarray(rnd["aq"])
        m = len(rnd["z"])
        if not use_stage1:
            # static configuration, no edge-cloud partitioning
            fixed_r = np.full(m, sys.n_res // 2)
            fixed_p = np.full(m, sys.n_fps // 2)
            f = np.asarray(lat.accuracy(z))
            # robust version choice at the fixed config (worst-case u per v)
            u = np.asarray(lat.u_dev)
            b2 = np.asarray(lat.b2)
            cost_v = b2[fixed_r[0], fixed_p[0], :, 0] * (1 + u)
            feas = f[np.arange(m), fixed_r, fixed_p, :, 0] >= rnd["aq"][:, None]
            obj = np.where(feas, cost_v[None], BIG)
            v = obj.argmin(1)
            bad = ~feas.any(1)
            v[bad] = f[bad][:, fixed_r[0], fixed_p[0], :, 0].argmax(1)
            return {"route": np.zeros(m, np.int64), "r": fixed_r, "p": fixed_p, "v": v}
        if not use_stage2:
            # adaptive config but single mid model, nominal planning
            return _argmin_feasible(lat, rnd["z"], rnd["aq"],
                                    allowed_versions=[sys.num_versions // 2])
        sol = solve_ccg(prob, z, aq)
        if use_gate:
            # temporal consistency on routes vs previous round
            prev = state.get("prev_route")
            tau_proxy = jnp.asarray(rnd["z"])  # difficulty as gate proxy here
            prev_tau = state.get("prev_tau")
            if prev is not None:
                allowed = jnp.abs(tau_proxy - prev_tau) * 4.0 >= 1.0
                route = jnp.where(
                    (sol["route"] != prev) & ~allowed, prev, sol["route"]
                )
                sol = dict(sol, route=route)
            state["prev_route"] = np.asarray(sol["route"]).copy()
            state["prev_tau"] = np.asarray(tau_proxy).copy()
        sol2, _ = enforce_bandwidth(lat, sol, z, aq)
        return {k: np.asarray(sol2[k]) for k in ("route", "r", "p", "v")}
    return method


BASELINES = {
    "A2": a2_cloud_only,
    "JCAB": jcab,
    "RDAP": rdap,
    "Sniper": sniper,
    "R2E-VID": r2evid,
}


def make_method(name: str, sys: SystemConfig, **kw):
    # registry-name spellings (repro.serving.policy.POLICIES) resolve too,
    # so parity tests can address oracle and policy by one name; the map is
    # derived from the policy registry's aliases — one source of truth
    from repro.serving.policy import _ALIASES

    display = {registry: disp for disp, registry in _ALIASES.items()}
    return BASELINES[display.get(name, name)](sys, **kw)
