"""Round-based edge-cloud serving simulator (paper §4 evaluation substrate).

Each round, M video-segment tasks arrive (difficulty z from the synthetic
stream generator, accuracy requirements stable U[0.6,0.7] / fluctuating
U[0.5,0.8]).  A method maps tasks -> (route, r, p, v); the simulator then
realizes:

  transmission : data(r,p) / (tier bandwidth x fluctuation), shared fairly
  queueing     : tasks pack onto 4 edge servers / 1 cloud server,
                 least-loaded-first (paper hardware: 4x Jetson NX + 1 Xeon)
  compute      : version FLOPs / server throughput x adversarial-in-U jitter
  energy       : tier power x compute time + tx power x transmission
  accuracy     : accuracy_table(r, p, v, tier | z) + observation noise

Methods only see ẑ (their own difficulty estimate) and A^q; the realized u
(compute deviation) is drawn inside the Γ-budget uncertainty set — robust
methods should degrade gracefully, nominal ones overshoot their SLA.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_rounds: int = 20
    n_tasks: int = 60
    requirement: str = "stable"        # stable | fluctuating
    bw_fluctuation: float = 0.0        # 0..0.3: bandwidth dips up to this frac
    n_edge_servers: int = 4
    n_cloud_servers: int = 1
    seed: int = 0
    adversarial_u: bool = True         # realize u at a worst-ish pole of U


class Simulator:
    def __init__(self, sys: SystemConfig, sim: SimConfig):
        self.sys = sys
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)
        self.c1, self.b2, self.bw_tab = (np.asarray(t) for t in cost_tables(sys))

    # ------------------------------------------------------------------
    def sample_round(self):
        sim, rng = self.sim, self.rng
        z = np.clip(rng.beta(2.0, 2.5, sim.n_tasks) * 1.2, 0.02, 1.0)
        if sim.requirement == "stable":
            aq = rng.uniform(0.6, 0.7, sim.n_tasks)
        else:
            aq = rng.uniform(0.5, 0.8, sim.n_tasks)
        bw_mult = 1.0 - rng.uniform(0.0, sim.bw_fluctuation, 2)  # per tier
        # realized compute deviation in U (Γ largest versions get hit)
        u = np.zeros(self.sys.num_versions)
        if sim.adversarial_u:
            hit = rng.choice(self.sys.num_versions, self.sys.gamma, replace=False)
            u[hit] = self.sys.u_dev * (0.6 + 0.4 * hit / (self.sys.num_versions - 1))
        else:
            u = rng.uniform(0, self.sys.u_dev, self.sys.num_versions)
        return {"z": z.astype(np.float32), "aq": aq.astype(np.float32),
                "bw_mult": bw_mult, "u": u}

    # ------------------------------------------------------------------
    def realize(self, rnd, cfg):
        """cfg: dict(route, r, p, v) int arrays (M,). Returns per-task metrics."""
        sys, sim = self.sys, self.sim
        route = np.asarray(cfg["route"])
        r, p, v = (np.asarray(cfg[k]) for k in ("r", "p", "v"))
        m = route.shape[0]

        # --- transmission: fair-share the tier uplink among its tasks
        bw = np.array([sys.edge_bw_mbps, sys.cloud_bw_mbps]) * rnd["bw_mult"]
        data_mbit = self.bw_tab[r, p, route]
        t_trans = np.zeros(m)
        for tier in (0, 1):
            sel = route == tier
            n = max(sel.sum(), 1)
            share = bw[tier] / n
            t_trans[sel] = data_mbit[sel] / np.maximum(share, 1e-6)

        # --- compute + queueing: least-loaded-first packing
        gf = np.zeros(m)
        thr = np.array([sys.edge_gflops, sys.cloud_gflops])
        fps = np.asarray(sys.fps_options, np.float32)
        for i in range(m):
            from repro.core.cost_model import version_flops
            gf[i] = version_flops(sys, int(route[i]), int(v[i]),
                                  int(sys.resolutions[r[i]])) * fps[p[i]] * sys.segment_sec
        t_comp = gf / thr[route] * (1.0 + rnd["u"][v])
        t_queue = np.zeros(m)
        servers = {0: np.zeros(sim.n_edge_servers), 1: np.zeros(sim.n_cloud_servers)}
        order = np.argsort(-t_comp)  # longest-first packing
        for i in order:
            q = servers[int(route[i])]
            j = int(q.argmin())
            t_queue[i] = q[j]
            q[j] += t_comp[i]

        delay = t_trans + t_queue + t_comp
        power = np.array([sys.edge_power_w, sys.cloud_power_w])
        energy = power[route] * t_comp + sys.transmit_power_w * t_trans
        cost = delay + sys.beta * energy

        acc_tab = np.asarray(accuracy_table(sys, rnd["z"]))
        acc = acc_tab[np.arange(m), r, p, v, route]
        acc = np.clip(acc + self.rng.normal(0, 0.008, m), 0, 1)
        return {
            "delay": delay, "energy": energy, "cost": cost, "accuracy": acc,
            "success": (acc >= rnd["aq"] - 1e-6).astype(np.float32),
            "route": route,
        }

    # ------------------------------------------------------------------
    def run(self, method: Callable, n_rounds=None) -> Dict[str, float]:
        """method(round_dict, sim_state) -> cfg dict.  Aggregates metrics."""
        out = {k: [] for k in ("delay", "energy", "cost", "accuracy", "success", "cloud_frac")}
        state = {}
        for _ in range(n_rounds or self.sim.n_rounds):
            rnd = self.sample_round()
            cfg = method(rnd, state)
            met = self.realize(rnd, cfg)
            for k in ("delay", "energy", "cost", "accuracy", "success"):
                out[k].append(met[k].mean())
            out["cloud_frac"].append(met["route"].mean())
        return {k: float(np.mean(vs)) for k, vs in out.items()}
