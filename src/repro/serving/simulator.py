"""Round-based edge-cloud serving simulator (paper §4 evaluation substrate).

Each round, M video-segment tasks arrive (difficulty z from the synthetic
stream generator, accuracy requirements stable U[0.6,0.7] / fluctuating
U[0.5,0.8]).  A method maps tasks -> (route, r, p, v); the simulator then
realizes:

  transmission : data(r,p) / (tier bandwidth x fluctuation), shared fairly
  queueing     : tasks pack onto 4 edge servers / 1 cloud server,
                 longest-processing-time first (paper hardware: 4x Jetson NX
                 + 1 Xeon)
  compute      : version FLOPs / server throughput x adversarial-in-U jitter
  energy       : tier power x compute time + tx power x transmission
  accuracy     : accuracy_table(r, p, v, tier | z) + observation noise

Methods only see ẑ (their own difficulty estimate) and A^q; the realized u
(compute deviation) is drawn inside the Γ-budget uncertainty set — robust
methods should degrade gracefully, nominal ones overshoot their SLA.

The deterministic realization path is pure jnp (``realize_rounds``): per-
config GFLOPs come from the precomputed lattice table and LPT packing runs
as a compiled scan over sorted tasks (vectorized across servers, and across
whole rounds in ``realize_batch``).  The same compiled function backs
``realize``, ``realize_batch``, and the whole-run ``serve_scan`` driver
(``repro.serving.scan``), so the scan engine and the host-loop simulator are
bit-identical.  ``realize_reference`` keeps the original per-task Python
loop as the parity oracle for tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import SystemConfig, accuracy_at, version_flops
from repro.core.lattice import DecisionLattice, gflops_table


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_rounds: int = 20
    n_tasks: int = 60
    requirement: str = "stable"        # stable | fluctuating
    bw_fluctuation: float = 0.0        # 0..0.3: bandwidth dips up to this frac
    n_edge_servers: int = 4
    n_cloud_servers: int = 1
    seed: int = 0
    adversarial_u: bool = True         # realize u at a worst-ish pole of U

    def __post_init__(self):
        # fail loudly at construction: an out-of-range dip fraction silently
        # produced bw_mult traces outside the model's calibrated range, and a
        # typo'd requirement silently fell through to the fluctuating draw
        if not 0.0 <= self.bw_fluctuation <= 0.3:
            raise ValueError(
                f"bw_fluctuation must be in [0, 0.3], got "
                f"{self.bw_fluctuation!r} (scenario bandwidth traces go "
                f"through serving.scenarios, not this knob)")
        if self.requirement not in ("stable", "fluctuating"):
            raise ValueError(
                f"unknown requirement {self.requirement!r}; expected "
                f"'stable' or 'fluctuating'")


@partial(jax.jit, static_argnames=("n_edge", "n_cloud"))
def _lpt_queue(t_comp, route, n_edge: int, n_cloud: int, avail=None):
    """Longest-processing-time packing onto per-tier server pools.

    t_comp/route: (..., M) — leading batch dims are vmapped over rounds.
    Returns per-task queueing delay (load of the chosen server at placement).
    The scan is over sorted tasks; the argmin over servers is vectorized.

    ``avail``: optional (..., S) per-server availability (S = n_edge +
    n_cloud, edge servers first).  Dead servers start at infinite load so
    the argmin never places a task on them while any live server of the
    tier remains; with a whole tier dead the queue delay is inf (the route
    clamp in ``realize_rounds`` prevents that from being reachable).
    """
    def one_round(tc, rt, av=None):
        order = jnp.argsort(-tc)                      # stable, longest first
        tc_s = tc[order]
        rt_s = rt[order]
        server_tier = jnp.concatenate([
            jnp.zeros((n_edge,), jnp.int32), jnp.ones((n_cloud,), jnp.int32)
        ])
        init = jnp.zeros((n_edge + n_cloud,), t_comp.dtype)
        if av is not None:
            init = jnp.where(av > 0, init, jnp.inf)

        def body(loads, x):
            t, tier = x
            masked = jnp.where(server_tier == tier, loads, jnp.inf)
            j = masked.argmin()
            start = loads[j]
            return loads.at[j].add(t), start

        _, start_s = jax.lax.scan(body, init, (tc_s, rt_s))
        return jnp.zeros_like(tc).at[order].set(start_s)

    fn = one_round
    for _ in range(t_comp.ndim - 1):
        fn = jax.vmap(fn)
    if avail is None:
        return fn(t_comp, route.astype(jnp.int32))
    return fn(t_comp, route.astype(jnp.int32), avail)


def clamp_route_by_avail(route, avail, n_edge: int, n_cloud: int):
    """Route clamp against a server pool's availability: never realize on a
    tier with zero live servers (edge-down wins when both tiers are dead —
    matches the router's ``clamp_route_available`` ordering).  Shared by
    ``realize_rounds`` and the sharded session's partitioned realization,
    which must count post-clamp routes *before* exchanging the per-shard
    tier totals."""
    av = jnp.asarray(avail, jnp.float32)
    alive_e = av[..., :n_edge].sum(-1, keepdims=True)
    alive_c = av[..., n_edge:].sum(-1, keepdims=True)
    route = jnp.where(alive_c > 0, route, jnp.zeros_like(route))
    return jnp.where(alive_e > 0, route, jnp.ones_like(route))


@partial(jax.jit, static_argnames=("sys", "n_edge", "n_cloud", "hedge"))
def realize_rounds(sys: SystemConfig, z, bw_mult, u, route, r, p, v, *,
                   n_edge: int, n_cloud: int, avail=None, lat_mult=None,
                   hedge=None, task_mask=None, n_tier=None, tier_frac=None):
    """Deterministic realization in pure jnp (no observation noise).

    Shape-generic over leading batch dims: z/route/r/p/v are (..., M),
    bw_mult is (..., 2), u is (..., K).  Returns per-task delay / energy /
    cost / accuracy / route with the same leading dims.  This is the single
    realization path shared by ``Simulator.realize``, ``realize_batch``, and
    the whole-run ``serve_scan`` driver.

    Scenario fault model (all optional; ``None`` lowers the exact nominal
    program):

    ``avail``
        (..., S) per-server availability, edge servers first.  Routes
        pointing at a fully dead tier are clamped to the surviving tier
        (so no realized segment ever lands on a masked server), the tier
        uplink shrinks by the alive fraction, and the LPT packer skips
        dead servers.
    ``lat_mult``
        (..., M, 2) heavy-tailed latency multipliers: column 0 scales the
        primary dispatch, column 1 the hedged backup.
    ``hedge``
        static ``(quantile, cost)`` tuple — hedged dispatch fused into the
        compute time: a backup fires at the ``quantile`` deadline of the
        primary draws, finishing at ``deadline + backup_time + cost``; the
        task completes at the earlier of the two (``runtime.straggler``
        semantics).  Requires ``lat_mult``.
    ``task_mask``
        (..., M) bool alive mask (slot-pool churn).  Dead lanes are excluded
        from the fair-share tier counts, take zero compute time into the LPT
        packer (so alive lanes pack exactly as on the compacted pool — they
        sort last and add no server load), and come out with zeroed metrics
        and ``route = -1`` (no realized segment ever lands on a dead slot).
        Incompatible with ``hedge`` (the deadline quantile over a mixed
        alive/dead batch is undefined).
    ``n_tier`` / ``tier_frac``
        Partitioned-realization overrides (the hierarchical sharded session):
        when the caller packs each shard's segments onto a *slice* of the
        server pool, the uplink fair-share terms must still be computed
        against the GLOBAL tier task counts (``n_tier``: (..., 2)) and the
        global tier alive fraction (``tier_frac``: (..., 2)), exchanged as
        per-shard scalars.  ``None`` (the default) derives both locally —
        the exact dense program.
    """
    if task_mask is not None and hedge is not None:
        raise ValueError("hedged dispatch is not supported with task_mask "
                         "(the deadline quantile would mix dead lanes)")
    lat = DecisionLattice.build(sys)
    gtab = jnp.asarray(gflops_table(sys), jnp.float32)
    route = route.astype(jnp.int32)
    r, p, v = r.astype(jnp.int32), p.astype(jnp.int32), v.astype(jnp.int32)
    m = route.shape[-1]

    alive_frac = None
    if avail is not None:
        av = jnp.asarray(avail, jnp.float32)
        n_alive = jnp.stack([av[..., :n_edge].sum(-1),
                             av[..., n_edge:].sum(-1)], axis=-1)  # (..., 2)
        n_total = jnp.asarray([n_edge, n_cloud], jnp.float32)
        alive_frac = n_alive / n_total
        route = clamp_route_by_avail(route, av, n_edge, n_cloud)
    if tier_frac is not None:
        # partitioned pools: the uplink shrinks by the FLEET's alive
        # fraction, not this slice's (the clamp above stays local — a task
        # can only land on this slice's servers)
        alive_frac = jnp.asarray(tier_frac, jnp.float32)

    # --- transmission: fair-share the tier uplink among its tasks
    tier_bw = jnp.asarray([sys.edge_bw_mbps, sys.cloud_bw_mbps], jnp.float32)
    bw = tier_bw * bw_mult                                     # (..., 2)
    if alive_frac is not None:
        bw = bw * alive_frac
    data_mbit = lat.bw[r, p, route]                            # (..., M)
    mask = None if task_mask is None else jnp.asarray(task_mask, bool)
    if n_tier is not None:
        n_tier = jnp.asarray(n_tier)          # caller-exchanged global counts
    elif mask is not None:
        n_cloud_tasks = (route * mask).sum(axis=-1, keepdims=True)
        n_alive = mask.sum(axis=-1, keepdims=True)
        n_tier = jnp.concatenate(
            [n_alive - n_cloud_tasks, n_cloud_tasks], axis=-1)
    else:
        n_cloud_tasks = route.sum(axis=-1, keepdims=True)
        n_tier = jnp.concatenate([m - n_cloud_tasks, n_cloud_tasks], axis=-1)
    n_tier = jnp.maximum(n_tier, 1)
    share = (jnp.take_along_axis(bw, route, -1)
             / jnp.take_along_axis(n_tier, route, -1))
    t_trans = data_mbit / jnp.maximum(share, 1e-6)

    # --- compute: precomputed GFLOPs table + realized deviation u_v
    gf = gtab[r, p, v, route]
    thr = jnp.asarray([sys.edge_gflops, sys.cloud_gflops], jnp.float32)
    t_comp = gf / thr[route] * (1.0 + jnp.take_along_axis(u, v, -1))
    if mask is not None:
        # dead lanes take zero compute into the packer: they sort after
        # every alive lane (stable argsort) and add no load to any server
        t_comp = jnp.where(mask, t_comp, 0.0)

    if lat_mult is not None:
        lm = jnp.asarray(lat_mult, jnp.float32)
        primary = t_comp * lm[..., 0]
        if hedge is not None:
            hq, hcost = hedge
            deadline = jnp.quantile(primary, hq, axis=-1, keepdims=True)
            backup = t_comp * lm[..., 1] + deadline + hcost
            t_comp = jnp.where(primary > deadline,
                               jnp.minimum(primary, backup), primary)
        else:
            t_comp = primary
    elif hedge is not None:
        raise ValueError("hedge requires lat_mult (per-task latency draws)")

    # --- queueing: compiled LPT packing (vmapped over leading dims)
    t_queue = _lpt_queue(t_comp, route, n_edge, n_cloud,
                         None if avail is None else jnp.asarray(avail))

    delay = t_trans + t_queue + t_comp
    power = jnp.asarray([sys.edge_power_w, sys.cloud_power_w], jnp.float32)
    energy = power[route] * t_comp + sys.transmit_power_w * t_trans
    cost = delay + sys.beta * energy

    # pointwise accuracy at the chosen configs — same formula as the
    # (..., M, F, K) table, evaluated only at the M gathered entries
    acc = accuracy_at(sys, z, r, p, v, route)
    if mask is not None:
        zero = lambda x: jnp.where(mask, x, 0.0)
        return {"delay": zero(delay), "energy": zero(energy),
                "cost": zero(cost), "accuracy": zero(acc),
                "route": jnp.where(mask, route, -1)}
    return {"delay": delay, "energy": energy, "cost": cost,
            "accuracy": acc, "route": route}


class Simulator:
    def __init__(self, sys: SystemConfig, sim: SimConfig):
        self.sys = sys
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)
        self.lat = DecisionLattice.build(sys)
        self.c1, self.b2, self.bw_tab = (
            np.asarray(self.lat.c1), np.asarray(self.lat.b2), np.asarray(self.lat.bw)
        )
        # (N, Z, K, 2) GFLOPs per segment, hoisted out of the per-task loop
        self.gflops_tab = gflops_table(sys)

    # ------------------------------------------------------------------
    def sample_round(self):
        sim, rng = self.sim, self.rng
        z = np.clip(rng.beta(2.0, 2.5, sim.n_tasks) * 1.2, 0.02, 1.0)
        if sim.requirement == "stable":
            aq = rng.uniform(0.6, 0.7, sim.n_tasks)
        else:
            aq = rng.uniform(0.5, 0.8, sim.n_tasks)
        bw_mult = 1.0 - rng.uniform(0.0, sim.bw_fluctuation, 2)  # per tier
        # realized compute deviation in U (Γ largest versions get hit)
        u = np.zeros(self.sys.num_versions)
        if sim.adversarial_u:
            hit = rng.choice(self.sys.num_versions, self.sys.gamma, replace=False)
            u[hit] = self.sys.u_dev * (0.6 + 0.4 * hit / (self.sys.num_versions - 1))
        else:
            u = rng.uniform(0, self.sys.u_dev, self.sys.num_versions)
        return {"z": z.astype(np.float32), "aq": aq.astype(np.float32),
                "bw_mult": bw_mult, "u": u}

    # ------------------------------------------------------------------
    def _realize_deterministic(self, rnd, cfg):
        """Vectorized realization, minus observation noise (pure in rnd/cfg)."""
        met = realize_rounds(
            self.sys,
            jnp.asarray(rnd["z"], jnp.float32),
            jnp.asarray(rnd["bw_mult"], jnp.float32),
            jnp.asarray(rnd["u"], jnp.float32),
            jnp.asarray(cfg["route"]), jnp.asarray(cfg["r"]),
            jnp.asarray(cfg["p"]), jnp.asarray(cfg["v"]),
            n_edge=self.sim.n_edge_servers, n_cloud=self.sim.n_cloud_servers,
        )
        return {k: np.asarray(val) for k, val in met.items()}

    def observe(self, acc, aq):
        """Observation noise + SLA success — the single home of the noise
        model (σ=0.008) and success epsilon, shared by ``realize``,
        ``realize_batch``, and the scan driver's ``run_scan``."""
        acc = np.clip(np.asarray(acc) + self.rng.normal(0, 0.008, np.shape(acc)), 0, 1)
        return acc, (acc >= np.asarray(aq) - 1e-6).astype(np.float32)

    def realize(self, rnd, cfg):
        """cfg: dict(route, r, p, v) int arrays (M,). Returns per-task metrics."""
        met = self._realize_deterministic(rnd, cfg)
        acc, success = self.observe(met["accuracy"], rnd["aq"])
        return dict(met, accuracy=acc, success=success)

    # ------------------------------------------------------------------
    def realize_reference(self, rnd, cfg, noise=None):
        """Original per-task loop realization — parity oracle for ``realize``.

        ``noise``: optional (M,) accuracy observation noise; when None it is
        drawn from ``self.rng`` exactly like ``realize`` does.
        """
        sys, sim = self.sys, self.sim
        route = np.asarray(cfg["route"])
        r, p, v = (np.asarray(cfg[k]) for k in ("r", "p", "v"))
        m = route.shape[0]

        bw = np.array([sys.edge_bw_mbps, sys.cloud_bw_mbps]) * rnd["bw_mult"]
        data_mbit = self.bw_tab[r, p, route]
        t_trans = np.zeros(m)
        for tier in (0, 1):
            sel = route == tier
            n = max(sel.sum(), 1)
            share = bw[tier] / n
            t_trans[sel] = data_mbit[sel] / np.maximum(share, 1e-6)

        gf = np.zeros(m)
        thr = np.array([sys.edge_gflops, sys.cloud_gflops])
        fps = np.asarray(sys.fps_options, np.float32)
        for i in range(m):
            gf[i] = version_flops(sys, int(route[i]), int(v[i]),
                                  int(sys.resolutions[r[i]])) * fps[p[i]] * sys.segment_sec
        t_comp = gf / thr[route] * (1.0 + rnd["u"][v])
        t_queue = np.zeros(m)
        servers = {0: np.zeros(sim.n_edge_servers), 1: np.zeros(sim.n_cloud_servers)}
        order = np.argsort(-t_comp, kind="stable")  # longest-first packing
        for i in order:
            q = servers[int(route[i])]
            j = int(q.argmin())
            t_queue[i] = q[j]
            q[j] += t_comp[i]

        delay = t_trans + t_queue + t_comp
        power = np.array([sys.edge_power_w, sys.cloud_power_w])
        energy = power[route] * t_comp + sys.transmit_power_w * t_trans
        cost = delay + sys.beta * energy

        acc_tab = np.asarray(self.lat.accuracy(jnp.asarray(rnd["z"])))
        acc = acc_tab[np.arange(m), r, p, v, route]
        if noise is None:
            noise = self.rng.normal(0, 0.008, m)
        acc = np.clip(acc + noise, 0, 1)
        return {
            "delay": delay, "energy": energy, "cost": cost, "accuracy": acc,
            "success": (acc >= rnd["aq"] - 1e-6).astype(np.float32),
            "route": route,
        }

    # ------------------------------------------------------------------
    def realize_batch(self, rnds, cfgs):
        """Vectorized realization of R whole rounds in one pass.

        rnds: list of round dicts; cfgs: list of config dicts.  Returns
        per-task metric arrays of shape (R, M).  The LPT packing runs as one
        vmapped scan over all rounds.
        """
        z = np.stack([rd["z"] for rd in rnds])                        # (R, M)
        aq = np.stack([rd["aq"] for rd in rnds])
        n_rounds, m = z.shape
        met = realize_rounds(
            self.sys,
            jnp.asarray(z, jnp.float32),
            jnp.asarray(np.stack([rd["bw_mult"] for rd in rnds]), jnp.float32),
            jnp.asarray(np.stack([rd["u"] for rd in rnds]), jnp.float32),
            jnp.asarray(np.stack([np.asarray(c["route"]) for c in cfgs])),
            jnp.asarray(np.stack([np.asarray(c["r"]) for c in cfgs])),
            jnp.asarray(np.stack([np.asarray(c["p"]) for c in cfgs])),
            jnp.asarray(np.stack([np.asarray(c["v"]) for c in cfgs])),
            n_edge=self.sim.n_edge_servers, n_cloud=self.sim.n_cloud_servers,
        )
        met = {k: np.asarray(val) for k, val in met.items()}
        acc, success = self.observe(met["accuracy"], aq)
        return dict(met, accuracy=acc, success=success)

    # ------------------------------------------------------------------
    def sample_stream(self, n_rounds=None, dx_seq=None, feature_seed=None):
        """Sample R rounds into one round-stacked ``Observation`` stream.

        ``dx_seq``: optional (R, M, d) motion features for gate-mode
        policies; ``feature_seed`` synthesizes them from a dedicated rng
        instead (None leaves ``dx`` empty — τ-proxy / baseline policies
        never read it).
        """
        from repro.core.features import feature_dim
        from repro.serving.policy import Observation

        n = n_rounds or self.sim.n_rounds
        rnds = [self.sample_round() for _ in range(n)]
        if dx_seq is None and feature_seed is not None:
            frng = np.random.default_rng(feature_seed)
            dx_seq = jnp.asarray(
                frng.normal(size=(n, self.sim.n_tasks, feature_dim())),
                jnp.float32)
        return Observation(
            z=jnp.asarray(np.stack([rd["z"] for rd in rnds]), jnp.float32),
            aq=jnp.asarray(np.stack([rd["aq"] for rd in rnds]), jnp.float32),
            dx=dx_seq,
            bw_mult=jnp.asarray(np.stack([rd["bw_mult"] for rd in rnds]),
                                jnp.float32),
            u=jnp.asarray(np.stack([rd["u"] for rd in rnds]), jnp.float32),
        )

    def aggregate(self, mets, aq) -> Dict[str, float]:
        """Scalar run metrics from per-round (R, M) deterministic metrics:
        draws the observation noise (the single host-rng noise model in
        ``observe``) and averages — shared by ``run`` and the ``run_scan``
        shim so every driver reports identical keys."""
        acc, success = self.observe(np.asarray(mets["accuracy"]), np.asarray(aq))
        out = {k: float(np.asarray(mets[k]).mean(axis=1).mean())
               for k in ("delay", "energy", "cost")}
        out["accuracy"] = float(acc.mean(axis=1).mean())
        out["success"] = float(success.mean(axis=1).mean())
        out["cloud_frac"] = float(np.asarray(mets["route"]).mean(axis=1).mean())
        return out

    def run(self, policy, n_rounds=None, dx_seq=None, feature_seed=None,
            mesh=None) -> Dict[str, float]:
        """Serve a sampled stream through one compiled ``ServeSession.run``.

        ``policy`` is a :class:`~repro.serving.policy.Policy` (build one with
        ``make_policy``); the old ``method(rnd, state)`` host closures are no
        longer driven here — they survive only as parity oracles in
        :mod:`repro.serving.baselines`.

        NOTE the rng interleaving follows the old ``run_batch``, not the old
        per-round ``run``: all rounds are sampled before any observation
        noise is drawn, so fixed-seed scalars match pre-PR-5 ``run`` in
        distribution, not bit-for-bit.
        """
        from repro.serving.session import ServeSession

        if callable(policy) and not hasattr(policy, "decide"):
            raise TypeError(
                "Simulator.run now drives Policy objects through the "
                "compiled ServeSession; wrap the method via "
                "repro.serving.policy.make_policy")
        stream = self.sample_stream(n_rounds, dx_seq, feature_seed)
        session = ServeSession(
            policy, n_streams=self.sim.n_tasks, sim=self.sim, mesh=mesh)
        mets = session.run(stream)
        return self.aggregate(mets, stream.aq)

    def run_batch(self, policy, n_rounds=None) -> Dict[str, float]:
        """Deprecated alias of :meth:`run` (the realization has been fused
        into the compiled serve scan; there is no separate batch path)."""
        return self.run(policy, n_rounds)
