"""Unified routing-policy protocol: every router — R2E-VID and all four
baselines — as a registered-pytree bundle with a pure, scan-compatible step.

A :class:`Policy` owns its decision machinery (the shared
:class:`DecisionLattice` / :class:`RobustProblem` tables as pytree data, its
knobs as static metadata) and exposes

    init(n_streams)        -> state          (the per-stream carry pytree)
    decide(state, obs)     -> (state, sol)   (one round; pure jnp)

where ``obs`` is a frozen :class:`Observation` — the per-round observable
bundle (segment motion features, content difficulty, accuracy requirements,
plus the realization inputs the *simulator* consumes; policies never read the
realized ``u``).  Because ``decide`` is pure and the state is a pytree, any
policy runs compiled under ``lax.scan`` / ``shard_map`` — the
:class:`~repro.serving.session.ServeSession` driver gives every policy
batching, carry donation, and stream-axis sharding for free, so baseline
numbers and R2E-VID numbers come from the *same* compiled serve loop.

``decide`` splits into ``decide_stream`` (embarrassingly parallel over
streams — the shardable part) and ``repair`` (the cross-task tail, e.g. the
C6 bandwidth budget; identity for policies without one).  The contract for
sharded serving: ``repair`` may demote per-task fidelity but must not change
anything ``decide_stream``'s returned state depends on (C6 never flips a
route, so the locally-built carry stays exact).

The numpy host closures in :mod:`repro.serving.baselines` are retained as
the decision-for-decision parity oracles (tests/test_policy.py); the ports
here mirror them op for op:

  a2_cloud_only  [Jiang+ RTSS'21]   cloud-pinned nominal argmin
  jcab           [Wang+ INFOCOM'20] mid-ladder nominal, escalate on miss
  rdap           [Su+ 2022]         plans against an EMA difficulty forecast
                                    (the EMA lives in the scan carry)
  sniper         [Liu+ DAC'22]      similarity reuse against a first-round
                                    profile table (the table is the carry)
  r2evid         ours — with gate params: the streaming route_step path
                 (fused gate -> Stage-1 -> warm CCG -> temporal consistency
                 -> C6).  Without gate params: the τ-proxy port of the host
                 method adapter (cold CCG, difficulty-driven consistency).
                 Ablation flags (§4.4) match the host adapter.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig, accuracy_at
from repro.core.gating import GateConfig
from repro.core.lattice import DecisionLattice
from repro.core.robust import BIG, RobustProblem, solve_ccg_fused
from repro.core.router import (
    RouterConfig,
    RouterState,
    apply_temporal_consistency,
    clamp_route_available,
    enforce_bandwidth,
    init_router_state,
    route_segment,
    shard_bandwidth_target,
)


# ---------------------------------------------------------------------------
# Observation: the per-round observable bundle
# ---------------------------------------------------------------------------
@partial(
    jax.tree_util.register_dataclass,
    data_fields=("z", "aq", "dx", "bw_mult", "u", "tier_ok", "avail",
                 "lat_mult", "bw_scale", "arrive_n", "depart"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class Observation:
    """What one serving round exposes.  Single-round fields are (M,) /
    (M, d) / (2,) / (K,); a whole run stacks a leading R axis on every field
    and ``ServeSession.run`` scans over it.

    ``dx`` (segment motion features) is optional — policies without a gate
    ignore it.  ``bw_mult`` / ``u`` are *realization* inputs consumed by the
    simulator after the decision; no policy reads the realized ``u`` (the
    paper's information model: methods see ẑ and A^q only).

    The scenario-engine fields (all optional, ``None`` = benign round, the
    pre-scenario program bit-for-bit):

    * ``tier_ok`` (..., 2): per-tier availability the *router* sees —
      health-check knowledge, not adversary state.  An outaged tier is
      infeasible in Stage-1/CCG and clamped away post temporal consistency.
    * ``avail`` (..., S): per-server availability the *realization* sees
      (S = n_edge + n_cloud servers); dead servers take no queue load and
      shrink their tier's uplink share.
    * ``lat_mult`` (..., M, 2): heavy-tailed compute-latency multipliers
      (primary, backup replica) applied at realization; hedged dispatch
      races the backup when the primary blows the deadline quantile.
    * ``bw_scale`` (...,): scenario scale on the C6 bandwidth budget —
      scarcity the repair pass must plan against, distinct from the realized
      ``bw_mult`` fluctuation.

    The churn fields (slot-pool serving — both must be set together, and
    their presence routes ``ServeSession.run`` to the churn driver):

    * ``arrive_n`` (...,): number of new streams asking to join this round
      (Poisson / flash-crowd arrival trace).
    * ``depart`` (..., M): per-slot departure events — a True entry frees
      that slot this round.  Memoryless (geometric-lifetime) draws, so a
      per-(round, slot) Bernoulli trace is exact regardless of when the
      slot was last admitted.
    """
    z: jnp.ndarray                 # (..., M) content difficulty
    aq: jnp.ndarray                # (..., M) accuracy requirements A^q
    dx: Any = None                 # (..., M, d) motion features (gate input)
    bw_mult: Any = None            # (..., 2) per-tier bandwidth fluctuation
    u: Any = None                  # (..., K) realized compute deviation
    tier_ok: Any = None            # (..., 2) per-tier availability (router)
    avail: Any = None              # (..., S) per-server availability (realize)
    lat_mult: Any = None           # (..., M, 2) hedged latency multipliers
    bw_scale: Any = None           # (...,) C6 budget scale
    arrive_n: Any = None           # (...,) stream arrivals (churn)
    depart: Any = None             # (..., M) per-slot departures (churn)

    @property
    def n_streams(self) -> int:
        return self.z.shape[-1]

    @property
    def n_rounds(self) -> int:
        return self.z.shape[0]


def capacity_budget(sys: SystemConfig, tier_ok=None, bw_scale=None):
    """The round's planning bandwidth budget (Mbps) from the scenario's
    capacity telemetry, or ``None`` when no telemetry rides the observation
    (the nominal ``total_bw_mbps`` applies).

    ``bw_scale`` (measured capacity fraction) is the complete statement when
    present; otherwise the binary ``tier_ok`` availability derives the
    surviving tiers' share of the nominal uplink.  Shared by the C6 repair
    (:meth:`R2EVidPolicy.repair`) and the session's admission controller, so
    both plan against the *same* degraded budget.
    """
    if bw_scale is not None:
        return jnp.asarray(sys.total_bw_mbps, jnp.float32) * bw_scale
    if tier_ok is not None:
        cap = sys.edge_bw_mbps + sys.cloud_bw_mbps
        frac = (sys.edge_bw_mbps * (tier_ok[..., 0] > 0)
                + sys.cloud_bw_mbps * (tier_ok[..., 1] > 0)) / cap
        return jnp.asarray(sys.total_bw_mbps, jnp.float32) * frac
    return None


# ---------------------------------------------------------------------------
# Shared vectorized nominal argmin (the jnp port of
# baselines._argmin_feasible — same ops in the same order, so decisions are
# identical to the host oracle bit for bit)
# ---------------------------------------------------------------------------
def _argmin_feasible_jnp(lat: DecisionLattice, z, aq, *, force_route=None,
                         allowed_versions=None, margin=None, tier_ok=None):
    sys = lat.sys
    if margin is None:
        margin = sys.acc_margin_nominal
    f_flat = lat.accuracy_flat(z)                                  # (M, F, K)
    if tier_ok is not None:
        # outaged tiers: infeasible AND out of the max-accuracy fallback
        f_flat = jnp.where(lat.tier_y_ok(tier_ok)[..., None] > 0, f_flat, -BIG)
    total = lat.c1_flat[None, :, None] + lat.b2_flat[None]
    feas = f_flat >= (aq + margin)[:, None, None]
    if force_route is not None:
        y_route, _, _ = lat.unflatten_index(jnp.arange(lat.n_flat))
        feas = feas & (y_route == force_route)[None, :, None]
    if allowed_versions is not None:
        mv = jnp.zeros((sys.num_versions,), bool)
        mv = mv.at[jnp.asarray(allowed_versions)].set(True)
        feas = feas & mv[None, None, :]
    obj = jnp.where(feas, jnp.broadcast_to(total, feas.shape), BIG)
    flat = obj.reshape(obj.shape[0], -1)
    idx = flat.argmin(axis=1)
    # fall back to max-accuracy config when nothing is feasible
    none_ok = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0] >= BIG
    best_acc = f_flat.reshape(f_flat.shape[0], -1).argmax(axis=1)
    idx = jnp.where(none_ok, best_acc, idx)
    y = idx // sys.num_versions
    v = idx % sys.num_versions
    route, r, p = lat.unflatten_index(y)
    return {"route": route, "r": r, "p": p, "v": v}


# ---------------------------------------------------------------------------
# Policy protocol
# ---------------------------------------------------------------------------
class Policy:
    """Base protocol.  Subclasses are frozen registered-dataclass pytrees:
    tables (lattice / robust problem / gate params) are data fields, knobs
    are static metadata — so a policy instance passes straight through
    ``jax.jit`` with its config hashed as part of the compilation key."""

    name: str = "policy"
    #: whether ``decide_stream`` is per-task independent (safe to run on a
    #: local stream shard).  Sniper's profile table couples tasks globally
    #: unless its replicated-profile variant preseeds it (the default).
    shardable: bool = True
    #: whether the per-stream carry is identical on every device (global
    #: memory, e.g. sniper's profile table) rather than sharded over
    #: streams.  The sharded session then keeps the state replicated and
    #: calls :meth:`preseed_sharded` once at run start.
    state_replicated: bool = False

    def init(self, n_streams: int):
        """Fresh per-stream carry (any pytree; () for stateless policies)."""
        raise NotImplementedError

    def decide_stream(self, state, obs: Observation):
        """Per-stream portion of the step — no cross-task reductions."""
        raise NotImplementedError

    def repair(self, sol, z, aq, tier_ok=None, bw_scale=None, task_mask=None):
        """Cross-task tail on the full (gathered) batch; identity default.

        ``tier_ok`` / ``bw_scale`` carry the scenario's capacity state so a
        repair pass can plan against the *degraded* budget; ``task_mask`` is
        the slot pool's alive bitmask (dead lanes must not consume budget);
        policies without a repair ignore them.
        """
        return sol

    def repair_local(self, sol, z, aq, *, axis_name, tier_ok=None,
                     bw_scale=None, task_mask=None):
        """Hierarchical cross-task tail on this device's LOCAL stream shard.

        The sharded session's ``hierarchical=True`` mode calls this instead
        of gathering the batch for :meth:`repair`; implementations may only
        exchange O(n_devices) *scalars* over ``axis_name`` (the per-shard
        sub-budget split — see ``docs/SHARDING.md``), never any (M, ...)
        array.  Same contract as ``repair`` otherwise: demote fidelity,
        never flip a route.  Identity default for policies without a tail.
        """
        return sol

    def preseed_sharded(self, state, z, aq, tier_ok=None):
        """One-time run-start hook for replicated-state policies: build the
        global memory (e.g. sniper's first-round profile table) from the
        gathered round-0 ``(z, aq)`` so every device carries the same table
        without any in-scan collective.  Identity default."""
        return state

    def reset_streams(self, state, fresh):
        """Re-initialize the per-stream carry rows where ``fresh`` is True
        (slot reuse under churn): a re-admitted slot is a NEW stream and must
        not inherit the departed stream's gate cell / EMA / history.

        The default resets every state leaf whose leading axis is the stream
        axis row-wise against a fresh ``init``; leaves of any other shape
        (global memory, e.g. sniper's profile table) are left untouched by
        the :class:`SniperPolicy` override.
        """
        m = fresh.shape[0]
        init = self.init(m)

        def pick(i, x):
            if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == m:
                sel = fresh.reshape((m,) + (1,) * (x.ndim - 1))
                return jnp.where(sel, i, x)
            return x

        return jax.tree_util.tree_map(pick, init, state)

    def decide(self, state, obs: Observation):
        """One full round: per-stream decision + cross-task repair."""
        state, sol = self.decide_stream(state, obs)
        return state, self.repair(sol, obs.z, obs.aq, tier_ok=obs.tier_ok,
                                  bw_scale=obs.bw_scale)

    def pad_state(self, state, pad: int):
        """Grow every per-stream leaf by ``pad`` dummy streams (sharding)."""
        from repro.sharding.compat import pad_leading
        return jax.tree_util.tree_map(lambda x: pad_leading(x, pad), state)

    @property
    def lat(self) -> DecisionLattice:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Baselines (paper §4.1.1) as pure jnp policies
# ---------------------------------------------------------------------------
@partial(jax.tree_util.register_dataclass,
         data_fields=("_lat",), meta_fields=())
@dataclasses.dataclass(frozen=True)
class A2CloudOnlyPolicy(Policy):
    """A² — cloud-only joint model-and-data adaptation (stateless)."""
    _lat: DecisionLattice
    name = "a2_cloud_only"

    @property
    def lat(self):
        return self._lat

    def init(self, n_streams):
        return ()

    def decide_stream(self, state, obs):
        return state, _argmin_feasible_jnp(self._lat, obs.z, obs.aq,
                                           force_route=1, tier_ok=obs.tier_ok)


@partial(jax.tree_util.register_dataclass,
         data_fields=("_lat",), meta_fields=())
@dataclasses.dataclass(frozen=True)
class JCABPolicy(Policy):
    """JCAB — nominal single mid-ladder model, escalates version only where
    the mid model misses the requirement (stateless)."""
    _lat: DecisionLattice
    name = "jcab"

    @property
    def lat(self):
        return self._lat

    def init(self, n_streams):
        return ()

    def decide_stream(self, state, obs):
        lat = self._lat
        z, aq = obs.z, obs.aq
        mid = lat.sys.num_versions // 2
        cfg = _argmin_feasible_jnp(lat, z, aq, allowed_versions=[mid],
                                   tier_ok=obs.tier_ok)
        # the host oracle gathers the full accuracy table at the chosen
        # configs; the pointwise formula is bitwise the same check without
        # materializing the (M, N, Z, K, 2) table in the scan body
        ok = accuracy_at(lat.sys, z, cfg["r"], cfg["p"], cfg["v"],
                         cfg["route"]) >= aq
        esc = _argmin_feasible_jnp(lat, z, aq, tier_ok=obs.tier_ok)
        return state, {k: jnp.where(ok, cfg[k], esc[k]) for k in cfg}


class RDAPState(NamedTuple):
    z_ema: jnp.ndarray    # (M,) last observed difficulty (the EMA input)
    has: jnp.ndarray      # (M,) bool — False until the first round lands


@partial(jax.tree_util.register_dataclass,
         data_fields=("_lat",), meta_fields=("ema",))
@dataclasses.dataclass(frozen=True)
class RDAPPolicy(Policy):
    """RDAP — plans against an EMA difficulty forecast ẑ.  The EMA memory is
    the scan carry (the host closure's ``state["z_ema"]`` dict slot)."""
    _lat: DecisionLattice
    ema: float = 0.7
    name = "rdap"

    @property
    def lat(self):
        return self._lat

    def init(self, n_streams):
        return RDAPState(z_ema=jnp.zeros((n_streams,), jnp.float32),
                         has=jnp.zeros((n_streams,), bool))

    def decide_stream(self, state, obs):
        z = obs.z
        # NOTE: plans against the *forecast*; reality realizes obs.z
        z_hat = jnp.where(state.has, self.ema * state.z_ema + (1 - self.ema) * z, z)
        cfg = _argmin_feasible_jnp(self._lat, z_hat, obs.aq,
                                   tier_ok=obs.tier_ok)
        new = RDAPState(z_ema=z.astype(jnp.float32),
                        has=jnp.ones_like(state.has))
        return new, cfg


class SniperState(NamedTuple):
    key: jnp.ndarray      # (n_profiles, 2) profiled (z, aq) keys; +inf = empty
    route: jnp.ndarray    # (n_profiles,) profiled configs
    r: jnp.ndarray
    p: jnp.ndarray
    v: jnp.ndarray
    has: jnp.ndarray      # () bool — profile table captured yet?
    warmup: jnp.ndarray   # () bool — table preseeded at run start: emit the
    #                       per-task fresh configs this round (dense round-0
    #                       semantics), then start the similarity reuse


@partial(jax.tree_util.register_dataclass,
         data_fields=("_lat",), meta_fields=("n_profiles",
                                             "replicated_profile"))
@dataclasses.dataclass(frozen=True)
class SniperPolicy(Policy):
    """Sniper — similarity-aware reuse of the first round's profiled configs.
    The profile table is the carry; it is written exactly once (first round),
    matching the host closure.

    The nearest-profile match is a global cross-task lookup, so under stream
    sharding the table must be REPLICATED, not sharded: with
    ``replicated_profile=True`` (the default) the sharded session keeps the
    state on every device and preseeds the table once at run start from the
    gathered round-0 batch (:meth:`preseed_sharded` + the ``warmup`` flag
    keep round-0 decisions identical to the dense first-round capture).
    ``replicated_profile=False`` restores the historical refusal to run
    sharded at all."""
    _lat: DecisionLattice
    n_profiles: int = 8
    replicated_profile: bool = True
    name = "sniper"

    @property
    def shardable(self):
        return self.replicated_profile

    @property
    def state_replicated(self):
        return True

    @property
    def lat(self):
        return self._lat

    def init(self, n_streams):
        n = self.n_profiles
        return SniperState(
            key=jnp.full((n, 2), jnp.inf, jnp.float32),
            route=jnp.zeros((n,), jnp.int32), r=jnp.zeros((n,), jnp.int32),
            p=jnp.zeros((n,), jnp.int32), v=jnp.zeros((n,), jnp.int32),
            has=jnp.zeros((), bool), warmup=jnp.zeros((), bool),
        )

    def pad_state(self, state, pad):
        # no per-stream leaves: the (n_profiles, ...) table must never grow
        # with the stream padding
        return state

    def reset_streams(self, state, fresh):
        # the profile table is global cross-stream memory, not per-slot
        # state: a newly admitted stream simply matches against the existing
        # profiles (the similarity reuse the policy is built on), so slot
        # reuse resets nothing — and the default's leading-axis heuristic
        # must never touch the (n_profiles, ...) leaves
        return state

    def preseed_sharded(self, state, z, aq, tier_ok=None):
        """Build the round-0 profile table ahead of the scan (the sharded
        run's one-time gather): identical rows to the dense first-round
        capture, with ``warmup`` marking that round 0 must still emit the
        per-task fresh configs rather than table matches."""
        k = min(self.n_profiles, z.shape[0])
        fresh = _argmin_feasible_jnp(self._lat, z[:k], aq[:k],
                                     tier_ok=tier_ok)
        return SniperState(
            key=state.key.at[:k].set(jnp.stack([z[:k], aq[:k]], axis=1)),
            route=state.route.at[:k].set(fresh["route"].astype(jnp.int32)),
            r=state.r.at[:k].set(fresh["r"].astype(jnp.int32)),
            p=state.p.at[:k].set(fresh["p"].astype(jnp.int32)),
            v=state.v.at[:k].set(fresh["v"].astype(jnp.int32)),
            has=jnp.ones((), bool), warmup=jnp.ones((), bool),
        )

    def decide_stream(self, state, obs):
        z, aq = obs.z, obs.aq
        m = z.shape[0]
        n = self.n_profiles
        k = min(n, m)
        fresh = _argmin_feasible_jnp(self._lat, z, aq, tier_ok=obs.tier_ok)
        key = jnp.stack([z, aq], axis=1)                       # (M, 2)
        # reuse most-similar profiled config (the similarity shortcut);
        # +inf keys on unfilled profile rows keep them unreachable
        d = ((key[:, None, :] - state.key[None]) ** 2).sum(-1)  # (M, n)
        nn = d.argmin(axis=1)
        far = d.min(axis=1) > 0.02                       # profile refresh
        reused = {f: jnp.where(far, fresh[f], getattr(state, f)[nn])
                  for f in ("route", "r", "p", "v")}
        # a preseeded table still serves its capture round fresh (warmup)
        use_table = state.has & ~state.warmup
        sol = {f: jnp.where(use_table, reused[f], fresh[f]) for f in reused}
        if obs.tier_ok is not None:
            # a reused profile may point at a tier that has since died
            sol["route"] = clamp_route_available(sol["route"], obs.tier_ok)
        # first-round capture: profile the first k tasks, then freeze
        cap = {f: getattr(state, f).at[:k].set(fresh[f][:k].astype(jnp.int32))
               for f in ("route", "r", "p", "v")}
        new = SniperState(
            key=jnp.where(state.has, state.key,
                          state.key.at[:k].set(key[:k])),
            route=jnp.where(state.has, state.route, cap["route"]),
            r=jnp.where(state.has, state.r, cap["r"]),
            p=jnp.where(state.has, state.p, cap["p"]),
            v=jnp.where(state.has, state.v, cap["v"]),
            has=jnp.ones((), bool), warmup=jnp.zeros((), bool),
        )
        return new, sol


# ---------------------------------------------------------------------------
# R2E-VID
# ---------------------------------------------------------------------------
class HistoryState(NamedTuple):
    """τ-proxy carry: route/score history without a gate recurrence."""
    prev_route: jnp.ndarray   # (M,) int32, -1 = no previous segment
    prev_tau: jnp.ndarray     # (M,) float32


@partial(jax.tree_util.register_dataclass,
         data_fields=("prob", "gate_params"),
         meta_fields=("gate_cfg", "rcfg", "use_gate", "use_stage1",
                      "use_stage2", "force"))
@dataclasses.dataclass(frozen=True)
class R2EVidPolicy(Policy):
    """Ours.  Two operating modes plus the §4.4 ablations:

    * **gate mode** (``gate_params`` given): the streaming engine path —
      fused batched gate over ``obs.dx``, Stage-1, warm-started CCG,
      temporal consistency, C6 repair.  ``decide`` is exactly the
      ``route_step`` computation; the carry is :class:`RouterState`.
    * **τ-proxy mode** (``gate_params=None``): the port of the host method
      adapter — cold CCG + difficulty-driven temporal consistency + C6,
      with (prev_route, prev_z) as the carry.  Decision-identical to the
      retained ``baselines.r2evid`` closure.

    Ablations: ``use_stage1=False`` pins a static mid (r, p) on edge with
    only the robust version choice; ``use_stage2=False`` keeps the adaptive
    config but a fixed mid-ladder version, nominal planning.
    """
    prob: RobustProblem
    gate_params: Any = None
    gate_cfg: GateConfig | None = None
    rcfg: RouterConfig = RouterConfig()
    use_gate: bool = True
    use_stage1: bool = True
    use_stage2: bool = True
    force: str = "auto"
    name = "r2evid"

    def __post_init__(self):
        # gate mode always runs the streaming route_segment path, which
        # bakes the temporal-consistency constraint in — refuse a silently
        # null §4.4 no-gate ablation instead of reporting a wrong effect
        if not self.use_gate and self.gate_params is not None:
            raise ValueError(
                "use_gate=False is the τ-proxy-mode ablation; drop "
                "gate_params to run it")

    @property
    def lat(self):
        return self.prob.lat

    @property
    def _full(self) -> bool:
        return self.use_stage1 and self.use_stage2

    def init(self, n_streams):
        if not self._full:
            return ()
        if self.gate_params is not None:
            return init_router_state(self.gate_cfg, n_streams)
        return HistoryState(
            prev_route=-jnp.ones((n_streams,), jnp.int32),
            prev_tau=jnp.zeros((n_streams,), jnp.float32),
        )

    def pad_state(self, state, pad):
        from repro.sharding.compat import pad_leading
        if not self._full:
            return state
        # dummy streams must carry the no-history marker
        if self.gate_params is not None:
            return RouterState(
                prev_route=pad_leading(state.prev_route, pad, value=-1),
                prev_tau=pad_leading(state.prev_tau, pad),
                gate=jax.tree_util.tree_map(
                    lambda x: pad_leading(x, pad), state.gate),
            )
        return HistoryState(
            prev_route=pad_leading(state.prev_route, pad, value=-1),
            prev_tau=pad_leading(state.prev_tau, pad),
        )

    def decide_stream(self, state, obs):
        lat = self.prob.lat
        sys = lat.sys
        z, aq = obs.z, obs.aq
        if not self.use_stage1:
            # static configuration, no edge-cloud partitioning; robust
            # version choice at the fixed config (worst-case u per v)
            m = z.shape[0]
            fr, fp = sys.n_res // 2, sys.n_fps // 2
            fv = lat.accuracy(z)[:, fr, fp, :, 0]                   # (M, K)
            cost_v = lat.b2[fr, fp, :, 0] * (1.0 + lat.u_dev)       # (K,)
            feas = fv >= aq[:, None]
            v = jnp.where(feas, cost_v[None], BIG).argmin(axis=1)
            v = jnp.where(feas.any(axis=1), v, fv.argmax(axis=1))
            route = jnp.zeros((m,), jnp.int32)
            if obs.tier_ok is not None:
                route = clamp_route_available(route, obs.tier_ok)
            sol = {"route": route,
                   "r": jnp.full((m,), fr, jnp.int32),
                   "p": jnp.full((m,), fp, jnp.int32), "v": v}
            return state, sol
        if not self.use_stage2:
            # adaptive config but single mid model, nominal planning
            return state, _argmin_feasible_jnp(
                lat, z, aq, allowed_versions=[sys.num_versions // 2],
                tier_ok=obs.tier_ok)
        if self.gate_params is not None:
            new_gate, taus, sol = route_segment(
                self.prob, self.gate_cfg, self.gate_params, state,
                obs.dx, z, aq, self.rcfg, force=self.force,
                tier_ok=obs.tier_ok)
            new_state = RouterState(
                prev_route=sol["route"].astype(jnp.int32),
                prev_tau=taus.astype(jnp.float32),
                gate=new_gate,
            )
            return new_state, sol
        # τ-proxy mode: cold CCG, difficulty as the gate-score proxy
        sol = solve_ccg_fused(self.prob, z, aq, force=self.force,
                              tier_ok=obs.tier_ok)
        if self.use_gate:
            taus = z
            route = apply_temporal_consistency(
                sol["route"], state.prev_route, taus, state.prev_tau, self.rcfg)
            if obs.tier_ok is not None:
                route = clamp_route_available(route, obs.tier_ok)
            sol = dict(sol, route=route, tau=taus)
            state = HistoryState(prev_route=route.astype(jnp.int32),
                                 prev_tau=jnp.asarray(taus, jnp.float32))
        return state, sol

    def repair(self, sol, z, aq, tier_ok=None, bw_scale=None, task_mask=None):
        if not self._full:
            return sol
        sys = self.prob.lat.sys
        # plan C6 against the scenario's *degraded* budget: the traced scale
        # (collapse/recovery trace) times the surviving tiers' share of the
        # nominal uplink capacity.  None scenario fields leave total_budget
        # at None — the exact pre-scenario program.  The admission
        # controller derives the same number through capacity_budget, so
        # what C6 plans against is what admission admitted against.
        total_budget = capacity_budget(sys, tier_ok=tier_ok,
                                       bw_scale=bw_scale)
        sol, bw_hist = enforce_bandwidth(self.prob.lat, sol, z, aq,
                                         total_budget=total_budget,
                                         rounds=self.rcfg.repair_rounds,
                                         force=self.force,
                                         task_mask=task_mask)
        # route_step always exposed the repair's bandwidth trajectory;
        # keep it so the RouterEngine shim stays drop-in (the session's
        # serve output filters it out exactly like serve_scan did)
        sol["bw_history"] = bw_hist
        return sol

    def repair_local(self, sol, z, aq, *, axis_name, tier_ok=None,
                     bw_scale=None, task_mask=None):
        """Hierarchical C6: repair this shard against its sub-budget.

        One all-gather of TWO scalars per device — this shard's pre-repair
        bandwidth draw and its alive-lane weight — buys the fleet-wide
        headroom-granted target (:func:`shard_bandwidth_target`); the
        demotion itself then runs entirely shard-locally.  The targets sum
        to ``min(Σbw, B)``, so the composition satisfies C6 exactly
        whenever the dense repair does, and with one device the target is
        ``min(bw, B)`` — the dense program bit for bit.
        """
        if not self._full:
            return sol
        lat = self.prob.lat
        sys = lat.sys
        budget = capacity_budget(sys, tier_ok=tier_ok, bw_scale=bw_scale)
        if budget is None:
            budget = jnp.asarray(sys.total_bw_mbps, jnp.float32)
        bw_i = lat.solution_bandwidth(sol)
        if task_mask is not None:
            bw_i = jnp.where(task_mask, bw_i, 0.0)
            weight = task_mask.sum().astype(jnp.float32)
        else:
            weight = jnp.asarray(bw_i.shape[0], jnp.float32)
        target = shard_bandwidth_target(bw_i.sum(), weight, budget,
                                        axis_name)
        sol, bw_hist = enforce_bandwidth(lat, sol, z, aq,
                                         total_budget=target,
                                         rounds=self.rcfg.repair_rounds,
                                         force=self.force,
                                         task_mask=task_mask)
        sol["bw_history"] = bw_hist
        return sol


# ---------------------------------------------------------------------------
# Registry (the successor of baselines.make_method)
# ---------------------------------------------------------------------------
def _a2(sys: SystemConfig, **kw):
    return A2CloudOnlyPolicy(_lat=DecisionLattice.build(sys), **kw)


def _jcab(sys: SystemConfig, **kw):
    return JCABPolicy(_lat=DecisionLattice.build(sys), **kw)


def _rdap(sys: SystemConfig, **kw):
    return RDAPPolicy(_lat=DecisionLattice.build(sys), **kw)


def _sniper(sys: SystemConfig, **kw):
    return SniperPolicy(_lat=DecisionLattice.build(sys), **kw)


def _r2evid(sys: SystemConfig, **kw):
    return R2EVidPolicy(prob=RobustProblem.build(sys), **kw)


POLICIES = {
    "a2_cloud_only": _a2,
    "jcab": _jcab,
    "rdap": _rdap,
    "sniper": _sniper,
    "r2evid": _r2evid,
}

# the host-closure registry names (baselines.BASELINES) keep working
_ALIASES = {"A2": "a2_cloud_only", "JCAB": "jcab", "RDAP": "rdap",
            "Sniper": "sniper", "R2E-VID": "r2evid"}


def make_policy(name: str, sys: SystemConfig, **kw) -> Policy:
    """Build a registered policy by name (successor of ``make_method``).

    Accepts both the registry names (``a2_cloud_only`` … ``r2evid``) and the
    legacy ``BASELINES`` display names (``A2`` … ``R2E-VID``).
    """
    key = _ALIASES.get(name, name)
    if key not in POLICIES:
        raise KeyError(
            f"unknown policy {name!r}; registered: {sorted(POLICIES)}")
    return POLICIES[key](sys, **kw)
