"""ServeSession: one compiled, shardable serve driver for every policy.

The session is the single owner of the serving configuration bundle — the
:class:`SystemConfig` / :class:`GateConfig` / :class:`RouterConfig` arrive
inside the :class:`~repro.serving.policy.Policy`, the :class:`SimConfig`
(server pool sizes) and the mesh + stream padding live here — plus the kernel
``force=`` pins and the carry donation discipline.  Every registered policy
(R2E-VID and all four baselines) runs through the same three entry points:

  ``session.step(obs)``          one round (decide, and realize when the
                                 observation carries ``bw_mult``/``u``)
  ``session.run(stream)``        R rounds under ONE ``lax.scan`` with the
                                 realization fused into the scan body;
                                 per-round (R, M) metrics out
  ``session.run_sharded(mesh, stream)``
                                 the same run as ONE compiled *sharded*
                                 scan: the policy's per-stream stage runs on
                                 each device's local stream shard, the
                                 cross-task tail (``Policy.repair`` + LPT
                                 realization) on the all-gathered real-M
                                 batch — metrics identical to the dense path

``session.route(obs)`` / ``session.route_many(...)`` are the decide-only
fast paths backing the :class:`RouterEngine` deprecation shim.  The carry is
donated in every compiled driver (buffers reused, never copied per step) and
threaded through ``self.state``, so callers never handle donation manually.

Optional online gate fine-tuning (``finetune=FinetuneConfig``): the scan
carry additionally threads the gate parameters, and every ``resync_period``
rounds a realized-success gradient step (BCE of the gate scores τ against
the round's SLA misses, proximally anchored at the offline parameters —
paper §3.2's online adaptation driven by what actually happened) updates
them inside the compiled run.  ``finetune=None`` (the default) lowers the
exact same program as before — bit-identical, covered by
tests/test_session.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gating import gate_step_batch
from repro.serving.policy import Observation, Policy, capacity_budget
from repro.serving.simulator import SimConfig, realize_rounds

_MET_KEYS = ("delay", "energy", "cost", "accuracy")
_SOL_KEYS = ("route", "r", "p", "v", "tau")


@dataclasses.dataclass(frozen=True)
class FinetuneConfig:
    """Online gate fine-tuning knobs (off unless passed to the session)."""
    lr: float = 1e-3
    resync_period: int = 4     # apply one gradient step every this many rounds
    mu: float = 0.1            # proximal anchor weight (catastrophic-forgetting guard)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """SLA-aware admission control for slot-pool (churn) runs.

    The controller runs inside the serve scan each round, *before* the
    policies decide: it admits new streams only while every admitted stream
    could still be served at minimum fidelity within the round's bandwidth
    budget (``capacity_budget`` — the same number the C6 repair plans
    against, tightened by ``bw_scale`` / ``tier_ok`` telemetry), queues the
    overflow up to ``max_queue``, and drops the rest.  Streams admitted
    while the budget is below ``degrade_frac`` of nominal are pinned to
    minimum fidelity (r = p = v = 0) for their lifetime in the pool.
    Static — part of the compilation key.
    """
    max_queue: int = 64        # waiting arrivals carried in the scan carry
    margin: float = 0.05       # headroom fraction held back from the budget
    degrade_frac: float = 0.5  # budget/nominal below this => degrade mode
    init_alive: int | None = None   # slots occupied at round 0 (None = all)


def _churn_admit(alive, degr, queue, arrive_n, depart, budget, total_bw,
                 bw_floor, acfg: AdmissionConfig, valid):
    """One round of slot-pool bookkeeping + admission (pure jnp, in-scan).

    Departures free their slots first; then up to ``cap - n_alive`` of the
    waiting streams (``queue`` + this round's ``arrive_n``) are admitted
    into the lowest-indexed free slots, where ``cap`` is the largest pool
    size whose worst-case minimum-fidelity bandwidth (``bw_floor`` per
    stream) fits the round's budget less the safety margin.  That bound is
    the provable SLA statement: admission never creates a stream the C6
    repair cannot fit — zero admitted-then-infeasible segments.

    ``valid`` masks the physically usable slots (all-true on the dense
    path; excludes the sharding pad lanes on the sharded path).  Returns
    ``(alive, degr, queue, newly, admitted, dropped)``.
    """
    alive = alive & ~depart & valid
    n_alive = alive.sum()
    cap = jnp.floor(budget * (1.0 - acfg.margin) / bw_floor).astype(jnp.int32)
    cap = jnp.clip(cap, 0, valid.sum())
    free = valid & ~alive
    want = queue + arrive_n
    can = jnp.clip(cap - n_alive, 0, free.sum())
    admitted = jnp.minimum(want, can)
    backlog = want - admitted
    queue = jnp.minimum(backlog, acfg.max_queue)
    dropped = backlog - queue
    rank = jnp.cumsum(free.astype(jnp.int32))      # 1-indexed among free slots
    newly = free & (rank <= admitted)
    scarce = budget < acfg.degrade_frac * total_bw
    # a freed slot sheds its degrade pin BEFORE re-admission, so a slot
    # reused in the same round starts from the new stream's budget state
    degr = (degr & alive) | (newly & scarce)
    alive = alive | newly
    return alive, degr, queue, newly, admitted, dropped


def _round_output(sol, met):
    """The per-round scan output: deterministic metrics + the decisions."""
    out = {k: met[k] for k in _MET_KEYS}
    out.update({k: sol[k] for k in _SOL_KEYS if k in sol})
    return out


# ---------------------------------------------------------------------------
# Compiled drivers (module-level so the jit cache is shared across sessions;
# the policy's static metadata is part of the compilation key via its pytree
# treedef, its tables are traced operands)
# ---------------------------------------------------------------------------
@partial(jax.jit, donate_argnames=("state",))
def _decide_step(policy, state, obs):
    return policy.decide(state, obs)


@partial(jax.jit, donate_argnames=("state",))
def _decide_scan(policy, state, obs_seq):
    def body(st, obs):
        return policy.decide(st, obs)

    return jax.lax.scan(body, state, obs_seq)


def _realize_obs(sys, obs, sol, n_edge, n_cloud, hedge, task_mask=None,
                 n_tier=None, tier_frac=None):
    """The one realization call every serve driver shares: scenario fault
    inputs (per-server availability, hedged latency draws) ride on the
    observation; ``None`` fields lower the exact pre-scenario program.
    ``n_tier`` / ``tier_frac`` are the hierarchical sharded path's globally
    exchanged fair-share scalars (partitioned server pools)."""
    return realize_rounds(
        sys, obs.z, obs.bw_mult, obs.u, sol["route"], sol["r"], sol["p"],
        sol["v"], n_edge=n_edge, n_cloud=n_cloud,
        avail=obs.avail, lat_mult=obs.lat_mult, hedge=hedge,
        task_mask=task_mask, n_tier=n_tier, tier_frac=tier_frac,
    )


@partial(jax.jit, static_argnames=("n_edge", "n_cloud", "hedge"),
         donate_argnames=("state",))
def _serve_step(policy, state, obs, n_edge, n_cloud, hedge=None):
    sys = policy.lat.sys
    state, sol = policy.decide(state, obs)
    met = _realize_obs(sys, obs, sol, n_edge, n_cloud, hedge)
    return state, _round_output(sol, met)


@partial(jax.jit, static_argnames=("n_edge", "n_cloud", "hedge"),
         donate_argnames=("state",))
def _serve_run(policy, state, obs_seq, n_edge, n_cloud, hedge=None):
    sys = policy.lat.sys

    def body(st, obs):
        st, sol = policy.decide(st, obs)
        met = _realize_obs(sys, obs, sol, n_edge, n_cloud, hedge)
        return st, _round_output(sol, met)

    return jax.lax.scan(body, state, obs_seq)


def _churn_round(policy, sys, bw_floor, total_bw, acfg, n_edge, n_cloud,
                 valid, carry, obs):
    """One slot-pool serving round: admission -> state reset on slot reuse
    -> per-stream decision -> degrade clamp -> masked repair -> masked
    realization.  Shared verbatim by the compiled scan body
    (``_serve_run_churn``) and the host-loop oracle in tests, so the
    bit-identity assertion compares the same per-round program."""
    st, alive, degr, queue = carry
    budget = capacity_budget(sys, tier_ok=obs.tier_ok, bw_scale=obs.bw_scale)
    budget = total_bw if budget is None else budget
    alive, degr, queue, newly, admitted, dropped = _churn_admit(
        alive, degr, queue, obs.arrive_n, obs.depart, budget, total_bw,
        bw_floor, acfg, valid)
    st = policy.reset_streams(st, newly)
    st, sol = policy.decide_stream(st, obs)
    # streams admitted under scarcity serve at minimum fidelity for their
    # pool lifetime (the admission contract their cap was computed against)
    sol = dict(sol, **{k: jnp.where(degr, jnp.zeros_like(sol[k]), sol[k])
                       for k in ("r", "p", "v")})
    sol = policy.repair(sol, obs.z, obs.aq, tier_ok=obs.tier_ok,
                        bw_scale=obs.bw_scale, task_mask=alive)
    met = _realize_obs(sys, obs, sol, n_edge, n_cloud, None, task_mask=alive)
    out = _round_output(sol, met)
    out["route"] = met["route"]        # masked: -1 marks the dead slots
    out.update(alive=alive, queue_depth=queue, admitted=admitted,
               dropped=dropped)
    return (st, alive, degr, queue), out


@partial(jax.jit, static_argnames=("acfg", "n_edge", "n_cloud"),
         donate_argnames=("carry",))
def _serve_run_churn(policy, carry, obs_seq, acfg, n_edge, n_cloud):
    """``_serve_run`` on a fixed-capacity slot pool: the carry additionally
    threads the alive bitmask, the per-slot degrade pins, and the admission
    queue depth; the arrival/departure traces ride the round-stacked
    observation (``arrive_n`` / ``depart``) exactly like the scenario
    fields, so the whole churned run is still ONE ``lax.scan``."""
    sys = policy.lat.sys
    # the per-stream minimum-fidelity bandwidth bound the admission cap is
    # computed against: the worst tier's (r=0, p=0) draw
    bw_floor = policy.lat.bw[0, 0, :].max()
    total_bw = jnp.asarray(sys.total_bw_mbps, jnp.float32)
    valid = jnp.ones_like(carry[1])

    def body(c, obs):
        return _churn_round(policy, sys, bw_floor, total_bw, acfg, n_edge,
                            n_cloud, valid, c, obs)

    return jax.lax.scan(body, carry, obs_seq)


@partial(jax.jit, static_argnames=("ft", "n_edge", "n_cloud", "hedge"),
         donate_argnames=("carry",))
def _serve_run_finetune(policy, carry, obs_seq, anchor, ft, n_edge, n_cloud,
                        hedge=None):
    """``_serve_run`` with the gate parameters threaded through the carry.

    carry = (policy state, gate params, round index).  Every
    ``ft.resync_period`` rounds one SGD step minimizes the realized-success
    BCE: τ should open (offload) exactly where this round's deterministic
    accuracy missed the requirement.  The gradient is truncated to the
    current round's gate cell (the carried recurrence is stop-gradiented),
    and a proximal term μ/2·‖θ − θ_offline‖² anchors against forgetting.
    """
    sys = policy.lat.sys
    gcfg = policy.gate_cfg

    def body(c, obs):
        st, params, i = c
        pol = dataclasses.replace(policy, gate_params=params)
        new_st, sol = pol.decide(st, obs)
        met = _realize_obs(sys, obs, sol, n_edge, n_cloud, hedge)
        fail = (met["accuracy"] < obs.aq).astype(jnp.float32)   # SLA misses

        def loss_fn(p):
            frozen = jax.tree_util.tree_map(jax.lax.stop_gradient, st.gate)
            # force="ref": the jnp cell is the differentiable twin of the
            # Pallas gate_cell (value parity is kernel-tested); the kernel
            # has no VJP, so auto-dispatch would fail under grad on TPU
            _, (taus, _) = gate_step_batch(gcfg, p, frozen, obs.dx,
                                           force="ref")
            eps = 1e-6
            bce = -(fail * jnp.log(taus + eps)
                    + (1.0 - fail) * jnp.log(1.0 - taus + eps)).mean()
            prox = sum(
                jnp.sum(jnp.square(a - b))
                for a, b in zip(jax.tree_util.tree_leaves(p),
                                jax.tree_util.tree_leaves(anchor))
            )
            return bce + 0.5 * ft.mu * prox

        params = jax.lax.cond(
            (i + 1) % ft.resync_period == 0,
            lambda p: jax.tree_util.tree_map(
                lambda a, g: a - ft.lr * g, p, jax.grad(loss_fn)(p)),
            lambda p: p,
            params,
        )
        return (new_st, params, i + 1), _round_output(sol, met)

    return jax.lax.scan(body, carry, obs_seq)


@partial(jax.jit, static_argnames=("n_edge", "n_cloud", "mesh", "mesh_axis",
                                   "has_dx", "hedge", "acfg", "hierarchical"))
def _serve_run_sharded(policy, state, obs_seq, n_edge, n_cloud, mesh,
                       mesh_axis, has_dx, hedge=None, churn=None, acfg=None,
                       hierarchical=False):
    """One compiled sharded scan over the whole run, for ANY shardable policy.

    The policy's per-stream stage (``decide_stream``) runs on each device's
    local shard of the stream axis M (padded to a multiple of the device
    count with dummy streams that the policy's ``pad_state`` marks inert).
    The cross-task tail then runs in one of two modes:

    * **gathered** (``hierarchical=False``, the parity oracle): the
      decisions are all-gathered so ``Policy.repair`` + LPT realization run
      on the exact real-M batch — replicated arithmetic, hence metrics
      identical to the dense path, at the cost of one O(M) collective per
      round.
    * **hierarchical** (``hierarchical=True``): NO (M, ...) array ever
      crosses devices inside the round body.  ``Policy.repair_local``
      repairs each shard against its scalar-exchanged C6 sub-budget
      (:func:`repro.core.router.shard_bandwidth_target`), and realization
      packs each shard's segments onto a statically partitioned slice of
      the server pool, with only the per-shard tier task counts (psum of 2
      ints) and the tier alive fractions exchanged for the uplink
      fair-share terms.  C6 is met exactly; queueing delay reflects the
      partitioned pools (see docs/SHARDING.md for the contract and bound).
      Requires ``n_edge`` / ``n_cloud`` divisible by the device count;
      incompatible with ``hedge`` (the deadline quantile is a global order
      statistic).

    Either way the carry stays local: the repair is contractually forbidden
    from changing anything the per-stream state depends on (C6 demotes
    fidelity, never flips routes), so the locally-built state is already
    exact.  Replicated-state policies (sniper's profile table) instead keep
    their carry whole on every device and are preseeded once at run start
    from the gathered round-0 batch (``Policy.preseed_sharded``) — the one
    O(M) gather those policies need, outside the scan.

    ``churn`` (optional): the slot pool's ``(alive, degr, queue)`` carry at
    real M.  The admission controller runs replicated (identical
    deterministic arithmetic per device — padding lanes are excluded via a
    static ``valid`` mask so they are never admitted); only the slot-reset
    mask is sliced down to the local shard.  ``None`` lowers the exact
    churn-free program.
    """
    from jax.sharding import PartitionSpec as P

    from repro.serving.simulator import clamp_route_by_avail
    from repro.sharding.compat import pad_leading, shard_map

    m = obs_seq.z.shape[1]
    n_dev = mesh.shape[mesh_axis]
    pad = (-m) % n_dev
    m_pad = m + pad
    if hierarchical:
        if hedge is not None:
            raise ValueError("hierarchical sharding cannot hedge (the "
                             "deadline quantile is a global order statistic)")
        if n_edge % n_dev or n_cloud % n_dev:
            raise ValueError(
                f"hierarchical sharding partitions the server pool "
                f"statically: n_edge={n_edge} and n_cloud={n_cloud} must "
                f"both divide by the {n_dev}-device mesh")
    e_l, c_l = n_edge // n_dev, n_cloud // n_dev

    pad_streams = lambda x: pad_leading(x, pad, axis=1)
    # lat_mult is per-task: the hierarchical realization consumes it on the
    # local shard, the gathered one on the replicated real-M batch
    lat_mult = obs_seq.lat_mult
    if hierarchical and lat_mult is not None:
        lat_mult = pad_streams(lat_mult)
    obs_seq = Observation(
        z=pad_streams(obs_seq.z),
        aq=pad_streams(obs_seq.aq),
        dx=pad_streams(obs_seq.dx) if has_dx else None,
        bw_mult=obs_seq.bw_mult,
        u=obs_seq.u,
        # the remaining scenario fields stay replicated: tier_ok / bw_scale
        # feed the per-stream decision and the repair budget, avail the
        # realization tail (sliced per shard in hierarchical mode) — none
        # of them shard over streams
        tier_ok=obs_seq.tier_ok,
        avail=obs_seq.avail,
        lat_mult=lat_mult,
        bw_scale=obs_seq.bw_scale,
        arrive_n=obs_seq.arrive_n,
        # the departure trace feeds the replicated admission arithmetic at
        # padded width (pad lanes never alive, so their entries are inert)
        depart=None if obs_seq.depart is None else pad_streams(obs_seq.depart),
    )
    if not policy.state_replicated:
        state = policy.pad_state(state, pad)
    if churn is not None:
        alive0, degr0, queue0 = churn
        churn = (pad_leading(alive0, pad), pad_leading(degr0, pad), queue0)
    sys = policy.lat.sys
    total_bw = jnp.asarray(sys.total_bw_mbps, jnp.float32)
    valid = jnp.arange(m_pad) < m

    def shard_body(pol, st_l, churn_c, dx_l, z_l, aq_l, bwm_seq, u_seq,
                   scn_seq, churn_seq):
        bw_floor = pol.lat.bw[0, 0, :].max()
        m_local = z_l.shape[1]
        start = jax.lax.axis_index(mesh_axis) * m_local
        slice_l = lambda x: jax.lax.dynamic_slice(x, (start,), (m_local,))
        valid_l = slice_l(valid)
        if pol.state_replicated:
            # the one O(M) gather a global-memory policy needs, ONCE at run
            # start (outside the scan): preseed the replicated table from
            # the gathered round-0 batch
            g0 = lambda x: jax.lax.all_gather(
                x[0], mesh_axis, axis=0, tiled=True)[:m]
            t0 = None if scn_seq[0] is None else scn_seq[0][0]
            st_l = pol.preseed_sharded(st_l, g0(z_l), g0(aq_l), tier_ok=t0)

        def body(c, xs):
            st, churn_c = c
            dx, z, aq, bwm, u, scn, chn = xs
            tier_ok, avail, lat_mult, bw_scale = scn
            task_mask = degr_l = None
            churn_out = {}
            if churn_c is not None:
                alive, degr, queue = churn_c
                arr_n, dep = chn
                budget = capacity_budget(sys, tier_ok=tier_ok,
                                         bw_scale=bw_scale)
                budget = total_bw if budget is None else budget
                alive, degr, queue, newly, admitted, dropped = _churn_admit(
                    alive, degr, queue, arr_n, dep, budget, total_bw,
                    bw_floor, acfg, valid)
                # only this device's slice of the reset mask touches the
                # local carry
                st = pol.reset_streams(st, slice_l(newly))
                churn_c = (alive, degr, queue)
                task_mask = alive[:m]
                degr_l = slice_l(degr)
                churn_out = dict(queue_depth=queue, admitted=admitted,
                                 dropped=dropped)
            obs_l = Observation(z=z, aq=aq, dx=dx, tier_ok=tier_ok)
            st, sol = pol.decide_stream(st, obs_l)

            if hierarchical:
                # -- hierarchical tail: O(n_devices) scalars only ---------
                mask_l = (valid_l if churn_c is None
                          else slice_l(churn_c[0]))
                if degr_l is not None:
                    sol = dict(sol, **{
                        k: jnp.where(degr_l, jnp.zeros_like(sol[k]), sol[k])
                        for k in ("r", "p", "v")})
                sol = pol.repair_local(sol, z, aq, axis_name=mesh_axis,
                                       tier_ok=tier_ok, bw_scale=bw_scale,
                                       task_mask=mask_l)
                tier_frac = avail_l = None
                route_c = sol["route"].astype(jnp.int32)
                if avail is not None:
                    # this shard's statically partitioned server-pool slice
                    avail_l = jnp.concatenate([
                        jax.lax.dynamic_slice(
                            avail[:n_edge],
                            (jax.lax.axis_index(mesh_axis) * e_l,), (e_l,)),
                        jax.lax.dynamic_slice(
                            avail[n_edge:],
                            (jax.lax.axis_index(mesh_axis) * c_l,), (c_l,)),
                    ])
                    route_c = clamp_route_by_avail(route_c, avail_l, e_l, c_l)
                    n_alive_g = jnp.stack([avail[:n_edge].sum(),
                                           avail[n_edge:].sum()])
                    tier_frac = n_alive_g / jnp.asarray(
                        [n_edge, n_cloud], jnp.float32)
                # global fair-share counts: psum of TWO ints per device
                ncl = (route_c * mask_l).sum()
                n_tier_g = jax.lax.psum(
                    jnp.stack([mask_l.sum() - ncl, ncl]), mesh_axis)
                obs_r = Observation(z=z, aq=aq, bw_mult=bwm, u=u,
                                    avail=avail_l, lat_mult=lat_mult)
                met = _realize_obs(pol.lat.sys, obs_r, sol, e_l, c_l, None,
                                   task_mask=mask_l, n_tier=n_tier_g,
                                   tier_frac=tier_frac)
                out = _round_output(sol, met)
                if churn_c is not None:
                    out["route"] = met["route"]
                    out["alive"] = mask_l
                return (st, churn_c), (out, churn_out)

            # -- gathered tail (the parity oracle): cross-task repair +
            # realization on the gathered REAL batch (padding dropped) —
            # identical arithmetic to the dense path on every device
            gather = lambda x: jax.lax.all_gather(
                x, mesh_axis, axis=0, tiled=True)[:m]
            z_g, aq_g = gather(z), gather(aq)
            sol_g = {k: gather(v) for k, v in sol.items()}
            if churn_c is not None:
                degr_m = churn_c[1][:m]
                sol_g = dict(sol_g, **{
                    k: jnp.where(degr_m, jnp.zeros_like(sol_g[k]), sol_g[k])
                    for k in ("r", "p", "v")})
            sol_g = pol.repair(sol_g, z_g, aq_g, tier_ok=tier_ok,
                               bw_scale=bw_scale, task_mask=task_mask)
            obs_g = Observation(z=z_g, aq=aq_g, bw_mult=bwm, u=u,
                                avail=avail, lat_mult=lat_mult)
            met = _realize_obs(pol.lat.sys, obs_g, sol_g, n_edge, n_cloud,
                               hedge, task_mask=task_mask)
            out = _round_output(sol_g, met)
            if churn_c is not None:
                out["route"] = met["route"]
                out["alive"] = task_mask
                out.update(churn_out)
            return (st, churn_c), out

        (st_l, churn_c), mets = jax.lax.scan(
            body, (st_l, churn_c),
            (dx_l, z_l, aq_l, bwm_seq, u_seq, scn_seq, churn_seq))
        return st_l, churn_c, mets

    state_spec = P() if policy.state_replicated else P(mesh_axis)
    dx_spec = P(None, mesh_axis) if has_dx else P()
    lat_spec = (P(None, mesh_axis)
                if hierarchical and obs_seq.lat_mult is not None else P())
    scn_seq = (obs_seq.tier_ok, obs_seq.avail, obs_seq.lat_mult,
               obs_seq.bw_scale)
    churn_seq = (None if churn is None
                 else (obs_seq.arrive_n, obs_seq.depart))
    # hierarchical metrics come out split: per-task leaves stay sharded
    # over streams, the admission scalars replicated
    mets_spec = (P(None, mesh_axis), P()) if hierarchical else P()
    final_state, final_churn, mets = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), state_spec, P(), dx_spec, P(None, mesh_axis),
                  P(None, mesh_axis), P(), P(), (P(), P(), lat_spec, P()),
                  P()),
        out_specs=(state_spec, P(), mets_spec), check_vma=False,
    )(policy, state, churn, obs_seq.dx, obs_seq.z, obs_seq.aq,
      obs_seq.bw_mult, obs_seq.u, scn_seq, churn_seq)
    if hierarchical:
        per_task, scalars = mets
        mets = {k: v[:, :m] for k, v in per_task.items()}
        mets.update(scalars)
    if not policy.state_replicated:
        final_state = jax.tree_util.tree_map(lambda x: x[:m], final_state)
    if final_churn is not None:
        alive_f, degr_f, queue_f = final_churn
        final_churn = (alive_f[:m], degr_f[:m], queue_f)
    return final_state, final_churn, mets


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------
class ServeSession:
    """Stateful owner of one policy's serving run.

    Parameters
    ----------
    policy : Policy
        Any registered policy (``make_policy``).  Carries the
        SystemConfig / GateConfig / RouterConfig bundle and the kernel
        ``force=`` preference; pass ``force=`` here to override the pin for
        the whole session.
    n_streams : int
        The stream/task batch size M the carry is sized for.
    sim : SimConfig, optional
        Realization-side configuration (server pool sizes).  ``n_edge`` /
        ``n_cloud`` override its fields.
    mesh, mesh_axis : optional
        Default mesh for ``run`` (``run_sharded`` takes an explicit one).
    finetune : FinetuneConfig, optional
        Enable the online gate fine-tuning carry (gate-mode r2evid only).
    hedge : (quantile, cost) tuple, optional
        Enable hedged dispatch inside the realization: a backup replica
        fires at the ``quantile`` deadline of the primary latency draws and
        the earlier finisher wins (+``cost`` dispatch overhead).  Only
        meaningful when the stream carries ``lat_mult`` draws (scenario
        engine); static — part of the compilation key.
    pools : dict, optional
        Tier -> :class:`~repro.serving.pools.ModelPool` live endpoints;
        ``dispatch`` maps a routed solution's token workloads onto them.
    admission : AdmissionConfig, optional
        Enable the slot-pool churn path: ``n_streams`` becomes the slot
        capacity M_cap and ``run`` expects ``arrive_n`` / ``depart`` traces
        on the stream.  The admission controller, slot recycling and
        alive-lane masking all run inside the one compiled scan.
    hierarchical : bool
        Default tail mode for :meth:`run_sharded`: ``True`` repairs and
        realizes per shard with only O(n_devices) scalars exchanged each
        round (hierarchical C6 sub-budgets + partitioned server pools),
        ``False`` (default) all-gathers the real-M batch — the parity
        oracle.  See :func:`_serve_run_sharded`.
    """

    def __init__(self, policy: Policy, n_streams: int, *,
                 sim: SimConfig | None = None,
                 n_edge: int | None = None, n_cloud: int | None = None,
                 mesh=None, mesh_axis: str = "data",
                 finetune: FinetuneConfig | None = None,
                 hedge: tuple | None = None,
                 admission: AdmissionConfig | None = None,
                 hierarchical: bool = False,
                 force: str | None = None, pools=None, state=None):
        if force is not None and hasattr(policy, "force"):
            policy = dataclasses.replace(policy, force=force)
        sim = sim or SimConfig()
        if hedge is not None:
            hq, hc = hedge   # must be a static (quantile, cost) pair
            hedge = (float(hq), float(hc))
            if not 0.0 < hedge[0] < 1.0:
                raise ValueError(f"hedge quantile must be in (0, 1), "
                                 f"got {hedge[0]}")
        self.policy = policy
        self.n_streams = n_streams
        self.sim_cfg = sim
        self.n_edge = sim.n_edge_servers if n_edge is None else n_edge
        self.n_cloud = sim.n_cloud_servers if n_cloud is None else n_cloud
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.pools = pools
        self._executor = None
        self.finetune = finetune
        self.hedge = hedge
        self.admission = admission
        self.hierarchical = hierarchical
        self._churn_carry = None
        self.state = policy.init(n_streams) if state is None else state
        self._rounds_done = jnp.zeros((), jnp.int32)
        if finetune is not None:
            if getattr(policy, "gate_params", None) is None:
                raise ValueError(
                    "finetune requires a gate-mode r2evid policy "
                    "(gate_params must be set)")
            # the proximal anchor: the offline parameters at session start
            self._anchor = jax.tree_util.tree_map(jnp.copy, policy.gate_params)
            # the finetune carry is donated every run — the session must own
            # its parameter buffers, not alias the caller's policy
            self.policy = dataclasses.replace(
                policy,
                gate_params=jax.tree_util.tree_map(jnp.copy, policy.gate_params))

    # -- config bundle accessors -------------------------------------------
    @property
    def sys_cfg(self):
        return self.policy.lat.sys

    @property
    def gate_params(self):
        return getattr(self.policy, "gate_params", None)

    # ----------------------------------------------------------------------
    def reset(self, n_streams: int | None = None):
        if n_streams is not None:
            self.n_streams = n_streams
        self.state = self.policy.init(self.n_streams)
        self._churn_carry = None
        self._rounds_done = jnp.zeros((), jnp.int32)

    def _churn_init(self):
        """Fresh slot-pool carry: the first ``init_alive`` slots occupied
        (all of them by default), no degrade pins, empty queue."""
        m = self.n_streams
        k = m if self.admission.init_alive is None \
            else min(self.admission.init_alive, m)
        return (jnp.arange(m) < k, jnp.zeros((m,), bool),
                jnp.zeros((), jnp.int32))

    def _check_churn(self, stream: Observation):
        if (stream.arrive_n is None) != (stream.depart is None):
            raise ValueError(
                "churn needs BOTH arrive_n and depart on the stream "
                "(one without the other is almost certainly a trace bug)")
        has_churn = stream.arrive_n is not None
        if has_churn and self.admission is None:
            raise ValueError(
                "stream carries churn traces (arrive_n/depart) but the "
                "session has no AdmissionConfig — pass admission= to "
                "ServeSession")
        if has_churn and self.finetune is not None:
            raise NotImplementedError(
                "online fine-tuning under stream churn is not supported")
        if has_churn and self.hedge is not None:
            raise ValueError(
                "hedged dispatch is not supported under churn (the hedge "
                "fair-share model has no alive-lane masking)")
        return has_churn

    def _check_obs(self, obs: Observation, rounds: bool):
        want = (2, 3) if rounds else (1, 2)
        if obs.z.ndim not in want:
            raise ValueError(f"Observation.z has rank {obs.z.ndim}; "
                             f"expected a {'round-stacked ' if rounds else ''}"
                             f"stream batch")
        if obs.z.shape[-1] != self.n_streams:
            raise ValueError(
                f"Observation carries {obs.z.shape[-1]} streams but the "
                f"session was sized for {self.n_streams}")

    # -- decide-only fast paths (RouterEngine / launch loop) ---------------
    def route(self, obs: Observation):
        """Route one segment batch (no realization).  Returns the solution."""
        self.state, sol = _decide_step(self.policy, self.state, obs)
        return sol

    def route_many(self, dx_seq, difficulty, acc_req):
        """Route S segment batches in one compiled ``lax.scan``.

        dx_seq: (S, M, d) (or None for gate-free policies); difficulty /
        acc_req: (M,) or (S, M).  Returns the stacked solutions.
        """
        if dx_seq is not None:
            s = dx_seq.shape[0]
        elif difficulty.ndim > 1:
            s = difficulty.shape[0]
        else:
            raise ValueError(
                "route_many cannot infer the segment count: pass dx_seq or "
                "round-stacked (S, M) difficulty/acc_req")
        if difficulty.ndim == 1:
            difficulty = jnp.broadcast_to(difficulty, (s,) + difficulty.shape)
        if acc_req.ndim == 1:
            acc_req = jnp.broadcast_to(acc_req, (s,) + acc_req.shape)
        obs_seq = Observation(z=difficulty, aq=acc_req, dx=dx_seq)
        self.state, sols = _decide_scan(self.policy, self.state, obs_seq)
        return sols

    # -- serve (decide + realize) ------------------------------------------
    def step(self, obs: Observation):
        """One serving round.  With ``bw_mult``/``u`` on the observation the
        round is realized and (sol+metrics) returned; without them this is
        ``route``."""
        self._check_obs(obs, rounds=False)
        if obs.u is None or obs.bw_mult is None:
            return self.route(obs)
        self.state, out = _serve_step(
            self.policy, self.state, obs, self.n_edge, self.n_cloud,
            self.hedge)
        return out

    def run(self, stream: Observation, n_rounds: int | None = None,
            mesh=None, mesh_axis: str | None = None):
        """Serve R rounds in one compiled scan (realization fused).

        ``stream``: an :class:`Observation` whose fields carry a leading
        round axis — (R, M[, d]) / (R, 2) / (R, K).  Returns the per-round
        metric dict of (R, M) arrays (deterministic delay / energy / cost /
        accuracy plus the decisions); observation noise stays the caller's
        job (it needs host rng state).  ``n_rounds`` slices a prefix.
        With a mesh (argument or session default) the run dispatches to
        :meth:`run_sharded`.
        """
        self._check_obs(stream, rounds=True)
        if stream.u is None or stream.bw_mult is None:
            raise ValueError("session.run needs bw_mult and u on the stream "
                             "(use route_many for decide-only scans)")
        if n_rounds is not None:
            stream = jax.tree_util.tree_map(lambda x: x[:n_rounds], stream)
        mesh = self.mesh if mesh is None else mesh
        if mesh is not None:
            return self.run_sharded(mesh, stream,
                                    mesh_axis=mesh_axis or self.mesh_axis)
        if self._check_churn(stream):
            if self._churn_carry is None:
                self._churn_carry = self._churn_init()
            alive, degr, queue = self._churn_carry
            carry = (self.state, alive, degr, queue)
            (self.state, alive, degr, queue), mets = _serve_run_churn(
                self.policy, carry, stream, self.admission, self.n_edge,
                self.n_cloud)
            self._churn_carry = (alive, degr, queue)
            return mets
        if self.finetune is not None:
            carry = (self.state, self.policy.gate_params, self._rounds_done)
            (self.state, params, self._rounds_done), mets = \
                _serve_run_finetune(self.policy, carry, stream, self._anchor,
                                    self.finetune, self.n_edge, self.n_cloud,
                                    self.hedge)
            self.policy = dataclasses.replace(self.policy, gate_params=params)
            return mets
        self.state, mets = _serve_run(
            self.policy, self.state, stream, self.n_edge, self.n_cloud,
            self.hedge)
        return mets

    def run_sharded(self, mesh, stream: Observation,
                    n_rounds: int | None = None, mesh_axis: str = "data",
                    hierarchical: bool | None = None):
        """The whole run as ONE compiled sharded scan over the stream axis.

        In the default gathered mode, metrics and the final carry are
        identical to the dense :meth:`run` (the cross-task tail runs on the
        all-gathered real-M batch); M pads to any device count.
        ``hierarchical=True`` (or the session default) switches the
        cross-task tail to per-shard sub-budget repair + partitioned-pool
        realization with O(n_devices) scalar exchange per round — exact C6,
        per-shard queueing (see docs/SHARDING.md).
        """
        self._check_obs(stream, rounds=True)
        if hierarchical is None:
            hierarchical = self.hierarchical
        if stream.u is None or stream.bw_mult is None:
            raise ValueError("session.run_sharded needs bw_mult and u on "
                             "the stream")
        if not self.policy.shardable:
            raise ValueError(
                f"policy {self.policy.name!r} couples tasks globally in "
                f"decide_stream and cannot run stream-sharded")
        if self.finetune is not None:
            raise NotImplementedError(
                "online fine-tuning is single-mesh only for now")
        if hierarchical and self.hedge is not None:
            raise ValueError(
                "hierarchical sharding cannot hedge: the deadline quantile "
                "is a global order statistic (use the gathered mode)")
        if n_rounds is not None:
            stream = jax.tree_util.tree_map(lambda x: x[:n_rounds], stream)
        has_churn = self._check_churn(stream)
        churn = acfg = None
        if has_churn:
            if self._churn_carry is None:
                self._churn_carry = self._churn_init()
            churn, acfg = self._churn_carry, self.admission
        self.state, churn, mets = _serve_run_sharded(
            self.policy, self.state, stream, self.n_edge, self.n_cloud,
            mesh, mesh_axis, stream.dx is not None, self.hedge,
            churn, acfg, hierarchical)
        if has_churn:
            self._churn_carry = churn
        return mets

    def run_elastic(self, stream: Observation, failures: dict, *,
                    mesh_axis: str = "data", n_nodes: int | None = None):
        """Serve through mid-run device loss: one sharded scan per epoch.

        ``failures``: {round -> iterable of node ids} killed *before* that
        round.  The run is segmented at failure boundaries; at each boundary
        the dead nodes are registered with a :class:`ClusterSim`,
        ``elastic_remesh(alive, prefer="data")`` rebuilds the survivor mesh,
        and the next segment continues under it with the carried stream
        state — the serving analogue of the trainer's restore-on-remesh
        recovery path.  Returns the per-round metrics concatenated across
        segments (identical keys to :meth:`run`); the mesh history is kept
        on ``self.mesh_history``.
        """
        import numpy as np

        from repro.runtime.cluster import ClusterSim, elastic_remesh

        self._check_obs(stream, rounds=True)
        r_total = stream.z.shape[0]
        cluster = ClusterSim(n_nodes or len(jax.devices()))
        # a malformed plan silently skipped here would make the run look
        # healthier than the experiment the caller asked for — fail loudly
        for r, nodes in failures.items():
            if not isinstance(r, (int, np.integer)) or not 0 < r < r_total:
                raise ValueError(
                    f"failures round {r!r} is outside the valid boundary "
                    f"range 1..{r_total - 1} (failures fire *before* a "
                    f"round; round 0 has no prior segment)")
            for node in nodes:
                if not 0 <= int(node) < cluster.n_nodes:
                    raise ValueError(
                        f"failures[{r}] names unknown node {node!r}; "
                        f"cluster has nodes 0..{cluster.n_nodes - 1}")
        bounds = sorted(failures)
        mesh = elastic_remesh(cluster.alive, prefer="data")
        self.mesh_history = [(0, mesh)]
        parts, start = [], 0
        for b in bounds + [r_total]:
            seg = jax.tree_util.tree_map(lambda x: x[start:b], stream)
            # segment metrics land on that epoch's mesh — pull them to host
            # so epochs served on different survivor sets concatenate
            parts.append({k: np.asarray(v) for k, v in
                          self.run_sharded(mesh, seg,
                                           mesh_axis=mesh_axis).items()})
            if b < r_total:
                for node in failures[b]:
                    cluster.kill(int(node))
                if cluster.alive <= 0:
                    raise RuntimeError(
                        f"all {cluster.n_nodes} nodes dead at round {b}; "
                        f"no survivor mesh to continue on")
                mesh = elastic_remesh(cluster.alive, prefer="data")
                self.mesh_history.append((b, mesh))
                # re-shard the carried per-stream state onto the survivors
                self.state = jax.tree_util.tree_map(
                    lambda x: jnp.asarray(np.asarray(x)), self.state)
            start = b
        return {k: jnp.asarray(np.concatenate([p[k] for p in parts], axis=0))
                for k in parts[0]}

    # -- live model pools ---------------------------------------------------
    def _make_executor(self):
        from repro.serving.dispatch import DispatchExecutor

        # slab sized for the largest fidelity the router can choose:
        # dispatch sizes prompts as 16·(1+r) with r < n_res
        return DispatchExecutor(
            self.pools, max_prefill_len=16 * self.sys_cfg.n_res)

    @property
    def executor(self):
        """The lazily built continuous-batching dispatch executor
        (:mod:`repro.serving.dispatch`) over the attached pools."""
        if self.pools is None:
            raise ValueError("session has no pools attached")
        if self._executor is None:
            self._executor = self._make_executor()
        return self._executor

    def dispatch(self, sol, decode_tokens: int = 8, serial: bool = False):
        """Execute a routed solution on the attached tier pools.

        Default: every routed segment becomes a :class:`Request` sized by
        ITS OWN chosen fidelity (``16·(1+r_i)`` prompt tokens) and the
        continuous-batching executor serves them — bucketed prefills,
        token-level decode across all in-flight segments per tier, tiers
        interleaved.  Dead lanes (``route == -1``, churned slots) are never
        enqueued.  Returns {tier: stats dict} with per-request latency
        p50/p99 and tokens/s (see ``DispatchExecutor.serve``).

        ``serial=True`` is the deprecated pre-executor path, kept as the
        scheduling oracle: one eager prefill+decode per tier, every
        segment sized by the tier-MEAN fidelity (the historical behavior —
        wrong for mixed-fidelity tiers, which is why it is no longer the
        default).  Returns the old bare {tier: n_segments} counts.
        """
        if self.pools is None:
            raise ValueError("session has no pools attached")
        import numpy as np

        if serial:
            served = {}
            for tier in (0, 1):
                idx = np.where(np.asarray(sol["route"]) == tier)[0]
                if len(idx) == 0:
                    continue
                # token budget scales with chosen fidelity (resolution x fps)
                n_tok = 16 * (1 + int(np.asarray(sol["r"])[idx].mean()))
                toks = jnp.ones((len(idx), n_tok), jnp.int32)
                self.pools[tier].serve_segment(toks,
                                               decode_tokens=decode_tokens)
                served[tier] = len(idx)
            return served

        from repro.serving.dispatch import Request

        route = np.asarray(sol["route"])
        r = np.asarray(sol["r"])
        reqs = []
        for i in range(route.shape[0]):
            tier = int(route[i])
            if tier < 0:        # churned / dead lane — never enqueued
                continue
            n_tok = 16 * (1 + int(r[i]))     # per-segment fidelity sizing
            vocab = self.pools[tier].cfg.vocab_size
            toks = (i * 131 + np.arange(n_tok)) % vocab
            reqs.append(Request(stream=i, tier=tier,
                                tokens=toks.astype(np.int32),
                                decode_tokens=decode_tokens))
        return self.executor.serve(reqs)

    def feedback(self):
        """The executor's measured per-tier serving state (see
        ``DispatchExecutor.feedback``)."""
        return self.executor.feedback()

    def apply_feedback(self, obs: Observation) -> Observation:
        """Fold the executor's measured per-tier state into an observation —
        the router ↔ serving loop the paper's Stage-2 assumes.

        The measured multiplier lands twice: on ``bw_mult`` (the realization
        sees the congested uplink) and, capacity-weighted across tiers, on
        ``bw_scale`` (the C6 repair plans against the shrunken budget — this
        is what actually changes the next round's decisions).  A session
        whose pools kept up returns the observation unchanged.
        """
        fb = self.feedback()
        mult = jnp.asarray(fb["bw_mult"], jnp.float32)[:2]
        sys = self.sys_cfg
        cap = sys.edge_bw_mbps + sys.cloud_bw_mbps
        scale = (sys.edge_bw_mbps * mult[0] + sys.cloud_bw_mbps * mult[1]) / cap
        if obs.z is not None and jnp.ndim(obs.z) >= 2:
            # round-stacked stream: every leaf needs the leading round axis
            # for the serve scan, so the (constant) measured state is tiled
            r = obs.z.shape[0]
            mult_seq = jnp.broadcast_to(mult, (r, 2))
            scale_seq = jnp.broadcast_to(scale, (r,))
        else:
            mult_seq, scale_seq = mult, scale
        return dataclasses.replace(
            obs,
            bw_mult=mult_seq if obs.bw_mult is None else obs.bw_mult * mult,
            bw_scale=scale_seq if obs.bw_scale is None else obs.bw_scale * scale,
        )
