"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter / activation dimension carries a *logical* axis name; a
``ShardingRules`` table maps logical names onto mesh axis names.  Rules differ
between training (FSDP + TP + SP) and serving (TP + sequence-sharded KV), and
architectures may override individual entries (e.g. mixtral decode shards
expert weights over the data axis to fit HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Union

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Sequence[str], None]

# ---------------------------------------------------------------------------
# Base tables.  "data" is the FSDP/DP axis; "model" is the TP/EP axis.  On a
# multi-pod mesh the "pod" axis is prepended to every entry that contains
# "data" (pure DP/FSDP scale-out across pods).
# ---------------------------------------------------------------------------

TRAIN_BASE: dict[str, Axis] = {
    # activations
    "batch": "data",
    "act_seq": None,          # sequence dim inside blocks
    "act_seq_sp": "model",    # sequence-parallel residual saves at layer edges
    "act_embed": None,
    # weights
    "embed": "data",          # FSDP shard of the d_model dim of weights
    "vocab": "model",
    "heads": "model",
    "heads_flat": "model",    # fused H*head_dim weight dim (always divisible)
    "kv_heads": "model",
    "head_dim": None,
    "qk": None,
    "mlp": "model",
    "experts": "model",
    "expert_in": "data",      # FSDP dim of expert weights
    "expert_mlp": None,
    "layers": None,           # scan dim (pipeline maps it to "pod")
    # ssm / rglru
    "inner": "model",
    "state": None,
    "conv": None,
    "dt_rank": None,
    "rglru_width": "model",
    # kv cache
    "cache_batch": "data",
    "cache_seq": "model",
    "cache_kv": None,
    "cache_dim": None,
}

SERVE_BASE: dict[str, Axis] = dict(
    TRAIN_BASE,
    **{
        "embed": None,        # no FSDP at serve time by default
        "act_seq_sp": None,
        "expert_in": None,
        # decode caches: batch over data, seq over model (kv-head counts are
        # rarely divisible by the TP degree).  GSPMD lowers the in-place
        # dynamic-update-slice on the sharded seq dim to a predicated local
        # update (no gather); decode attention computes sharded partial
        # softmax stats + a small all-reduce.
        "cache_seq": "model",
        "cache_kv": None,
    },
)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mapping: Mapping[str, Axis]
    mesh_axes: tuple[str, ...]
    mesh_sizes: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def axis(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        if name not in self.mapping:
            raise KeyError(f"unknown logical axis {name!r}")
        ax = self.mapping[name]
        return ax

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        used: set[str] = set()
        parts = []
        for name in logical_axes:
            ax = self.axis(name)
            if ax is None:
                parts.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            axes = tuple(a for a in axes if a in self.mesh_axes and a not in used)
            used.update(axes)
            if not axes:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, mesh: Mesh, logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes))

    def fitted_spec(
        self, logical_axes: Sequence[Optional[str]], shape: Sequence[int],
        sizes: Optional[Mapping[str, int]] = None,
    ) -> P:
        """Like ``spec`` but drops mesh axes that don't divide the dim.

        Used both for explicit input shardings (which REQUIRE divisibility)
        and for in-graph sharding constraints: constraining e.g. kv=8 heads
        onto a 16-way model axis makes GSPMD fall back to "involuntary full
        rematerialization" (replicate + repartition) — a measured 10x+
        collective/compute blowup on mixtral train (EXPERIMENTS.md §Perf).
        """
        sizes = sizes or self.mesh_sizes
        spec = self.spec(logical_axes)
        parts = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if entry is None:
                parts.append(None)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            while axes:
                total = 1
                for a in axes:
                    total *= sizes.get(a, 1)
                if dim % total == 0:
                    break
                axes = axes[:-1]
            if not axes:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def fitted_sharding(
        self, mesh: Mesh, logical_axes: Sequence[Optional[str]], shape: Sequence[int]
    ) -> NamedSharding:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        return NamedSharding(mesh, self.fitted_spec(logical_axes, shape, sizes))


def make_rules(
    mesh: Mesh,
    mode: str = "train",
    overrides: Optional[Mapping[str, Axis]] = None,
) -> ShardingRules:
    """Build a rule table adapted to ``mesh`` (handles the optional pod axis)."""
    base = dict(TRAIN_BASE if mode == "train" else SERVE_BASE)
    if overrides:
        base.update(overrides)
    mesh_axes = tuple(mesh.axis_names)
    multi_pod = "pod" in mesh_axes

    def adapt(ax: Axis) -> Axis:
        if ax is None:
            return None
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh_axes)
        if multi_pod and "data" in axes and "pod" not in axes:
            axes = ("pod",) + axes
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardingRules({k: adapt(v) for k, v in base.items()}, mesh_axes, sizes)


def logical_spec(rules: ShardingRules, *logical_axes: Optional[str]) -> P:
    return rules.spec(logical_axes)
