"""GPipe-style pipeline parallelism over a mesh "stage" axis.

The layer stack is split into S contiguous stages (stage s holds the
stacked params of its layers); microbatches stream through with
``jax.lax.ppermute`` moving activations stage-to-stage inside a
``shard_map``.  The schedule is the classic GPipe fill-drain: step t runs
microbatch (t - s) on stage s when 0 <= t - s < M, so wall-clock is
(M + S - 1) stage-steps and bubble fraction (S-1)/(M+S-1).

At fleet scale the natural mapping is stage := the "pod" axis (layers
split across pods; only activations cross the DCN, once per microbatch
per boundary) composed with the in-pod data/model mesh.  This module is
self-contained and validated on a fake multi-device mesh in
tests/test_pipeline.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.compat import shard_map  # noqa: F401 (re-export)


def split_stages(layer_params, n_stages: int):
    """Stack per-layer params (leading layer dim L) into (S, L//S, ...)."""

    def re(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree_util.tree_map(re, layer_params)


def pipeline(
    stage_fn: Callable,
    mesh: Mesh,
    *,
    axis: str = "stage",
    data_specs: P = P(),
):
    """Build a pipelined apply: (stage_params, microbatches) -> outputs.

    stage_fn(params_slice, x) applies ONE stage's layers to activations x.
    stage_params: pytree with leading stage dim S (see split_stages).
    microbatches: (M, ...) activations, fed to stage 0.
    Returns (M, ...) outputs of the final stage (replicated over `axis`).
    """
    s_count = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def inner(params, xs):
        # params arrives with the stage dim sharded away -> squeeze it
        params_local = jax.tree_util.tree_map(lambda p: p[0], params)
        sidx = jax.lax.axis_index(axis)
        m = xs.shape[0]
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros((m,) + xs.shape[1:], xs.dtype)

        def body(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t while t < M
            mb = jnp.clip(t, 0, m - 1)
            cur_in = jnp.where(sidx == 0, xs[mb], state)
            y = stage_fn(params_local, cur_in)
            # drain: last stage emits microbatch t-(S-1) when in range
            out_idx = t - (s_count - 1)
            valid = (out_idx >= 0) & (out_idx < m) & (sidx == s_count - 1)
            slot = jnp.clip(out_idx, 0, m - 1)
            outs = outs.at[slot].set(jnp.where(valid, y, outs[slot]))
            # fill: pass activations to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s_count) for i in range(s_count)]
            )
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(
            body, (state, outs), jnp.arange(m + s_count - 1)
        )
        # broadcast the last stage's outputs to every stage replica
        outs = jax.lax.psum(
            jnp.where(sidx == s_count - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    in_specs = (P(axis), data_specs)
    # check_vma=False: the scan carry starts replicated (zeros) and becomes
    # device-varying after the first ppermute — intentional for a pipeline
    return shard_map(
        inner, mesh=mesh, in_specs=in_specs, out_specs=data_specs, check_vma=False
    )


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
