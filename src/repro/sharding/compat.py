"""jax-version-compatible ``shard_map`` + batch-padding helpers.

jax >= 0.5 exports ``shard_map`` at the top level with a ``check_vma``
kwarg; older releases keep it under ``jax.experimental`` with ``check_rep``.
Every shard_map user in the repo (pipeline parallelism, the sharded CCG
sweep, the sharded ``serve_scan``, compressed collectives) goes through this
shim, and every sharded entry point that rounds a task/stream batch up to
the device count uses :func:`pad_leading`.
"""
from __future__ import annotations

import jax.numpy as jnp


def pad_leading(x, pad: int, value=0, axis: int = 0):
    """Pad the batch ``axis`` of ``x`` by ``pad`` rows of ``value``.

    The shared idiom behind M-to-any-device-count sharding: pad with inert
    dummies, shard, slice the real batch back out.  ``axis`` defaults to the
    leading axis; round-stacked (R, M, ...) streams pad ``axis=1`` directly
    instead of a moveaxis round-trip per field.
    """
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)

try:  # jax >= 0.5
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
except ImportError:  # older jax: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )
