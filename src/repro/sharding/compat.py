"""jax-version-compatible ``shard_map``.

jax >= 0.5 exports ``shard_map`` at the top level with a ``check_vma``
kwarg; older releases keep it under ``jax.experimental`` with ``check_rep``.
Every shard_map user in the repo (pipeline parallelism, the sharded CCG
sweep, compressed collectives) goes through this shim.
"""
from __future__ import annotations

try:  # jax >= 0.5
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
except ImportError:  # older jax: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )
