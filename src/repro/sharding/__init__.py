from repro.sharding.rules import (  # noqa: F401
    ShardingRules,
    make_rules,
    logical_spec,
    TRAIN_BASE,
    SERVE_BASE,
)
