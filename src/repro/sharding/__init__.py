from repro.sharding.compat import shard_map  # noqa: F401
from repro.sharding.rules import (  # noqa: F401
    ShardingRules,
    make_rules,
    logical_spec,
    TRAIN_BASE,
    SERVE_BASE,
)
