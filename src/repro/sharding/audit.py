"""Structural cross-device communication audit over jaxprs.

The hierarchical sharded serve path promises that NO (M, ...) array crosses
devices inside the per-round scan body — only O(n_devices) scalar stats.
That property is cheap to regress silently (one stray ``all_gather`` and the
fleet-scale story is gone), so instead of trusting the code we *measure* the
jaxpr: :func:`iter_collectives` walks every equation (recursing through
scan/cond/pjit/shard_map sub-jaxprs) and reports each collective primitive
with its largest operand size and whether it sits inside a ``scan`` body.
``tests/test_hierarchical.py`` asserts the invariant against it in CI.
"""
from __future__ import annotations

import math

import jax

#: primitive-name fragments that imply cross-device traffic under shard_map
COLLECTIVE_PRIMS = ("all_gather", "all_to_all", "psum", "pmax", "pmin",
                    "ppermute", "reduce_scatter", "pbroadcast")
#: loop primitives whose bodies are "the round body" for the audit
_LOOP_PRIMS = ("scan", "while")


def _sub_jaxprs(params):
    """Yield every (Closed)Jaxpr reachable from an eqn's params."""
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def iter_collectives(jaxpr, _in_loop=False):
    """Yield ``(prim_name, max_operand_elems, in_loop)`` for every collective
    equation reachable from ``jaxpr`` (a ``Jaxpr`` or ``ClosedJaxpr``).

    ``max_operand_elems`` is the element count of the largest input operand —
    the quantity that must stay O(n_devices) inside the hierarchical round
    body.  ``in_loop`` marks equations nested (at any depth) inside a
    ``scan``/``while`` body, i.e. executed every serving round.
    """
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(frag in name for frag in COLLECTIVE_PRIMS):
            size = 0
            for var in eqn.invars:
                aval = getattr(var, "aval", None)
                if aval is not None and getattr(aval, "shape", None) is not None:
                    size = max(size, int(math.prod(aval.shape)))
            yield name, size, _in_loop
        inner = _in_loop or any(frag in name for frag in _LOOP_PRIMS)
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_collectives(sub, inner)


def collective_footprint(fn, *args, **kwargs):
    """Trace ``fn(*args, **kwargs)`` and return its collectives as a list of
    ``(prim_name, max_operand_elems, in_loop)`` tuples."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return list(iter_collectives(jaxpr))


def max_loop_collective_elems(fn, *args, **kwargs):
    """The largest collective operand (in elements) executed inside any loop
    body of ``fn`` — 0 when loop bodies are collective-free.  The number the
    hierarchical serve path bounds by O(n_devices)."""
    return max((size for _, size, in_loop in
                collective_footprint(fn, *args, **kwargs) if in_loop),
               default=0)
