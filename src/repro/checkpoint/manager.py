"""Sharded checkpointing: msgpack + zstd, atomic, elastic-reshard restore.

Layout:  <dir>/step_<n>/manifest.msgpack  (tree structure + dtypes/shapes)
         <dir>/step_<n>/data.zst          (concatenated array payloads)

Restore accepts an optional sharding tree — arrays are ``device_put`` with
the *target* sharding, so a checkpoint written on a 16x16 mesh restores
cleanly onto a shrunken (elastic) mesh or a single host.

``zstandard`` is optional: without it payloads are written uncompressed and
the manifest records ``codec`` so either build can restore either format.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dependency; fall back to raw payloads
    zstandard = None


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, *, extra: Optional[dict] = None) -> str:
    leaves, treedef = _flatten(tree)
    codec = "zstd" if zstandard is not None else "raw"
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "codec": codec,
        "leaves": [],
    }
    payloads = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        manifest["leaves"].append(
            {"dtype": str(arr.dtype), "shape": list(arr.shape), "nbytes": arr.nbytes}
        )
        payloads.append(arr.tobytes())

    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".", prefix=".ckpt_tmp_")
    try:
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        with open(os.path.join(tmp, "data.zst"), "wb") as f:
            if codec == "zstd":
                cctx = zstandard.ZstdCompressor(level=3)
                with cctx.stream_writer(f) as w:
                    for p in payloads:
                        w.write(p)
            else:
                for p in payloads:
                    f.write(p)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)  # atomic publish
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def restore(path: str, target_tree: Any, *, shardings: Any = None):
    """target_tree supplies the pytree structure (values ignored)."""
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    codec = manifest.get("codec", "zstd")
    with open(os.path.join(path, "data.zst"), "rb") as f:
        if codec == "zstd":
            if zstandard is None:
                raise RuntimeError(
                    "checkpoint was written with codec='zstd' but zstandard "
                    "is not installed"
                )
            raw = zstandard.ZstdDecompressor().stream_reader(f).read()
        else:
            raw = f.read()

    leaves_meta = manifest["leaves"]
    arrays = []
    off = 0
    for meta in leaves_meta:
        n = meta["nbytes"]
        arr = np.frombuffer(raw[off : off + n], dtype=np.dtype(meta["dtype"]))
        arrays.append(arr.reshape(meta["shape"]))
        off += n

    t_leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(t_leaves) == len(arrays), (len(t_leaves), len(arrays))
    sh_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(arrays)
    )
    out = []
    for arr, ref, sh in zip(arrays, t_leaves, sh_leaves):
        a = jnp.asarray(arr, dtype=getattr(ref, "dtype", arr.dtype))
        if sh is not None:
            a = jax.device_put(a, sh)  # elastic re-shard onto the target mesh
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class CheckpointManager:
    """Retention + resume policy over ``save``/``restore``."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dirs(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append((int(d.split("_")[1]), os.path.join(self.dir, d)))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ds = self._step_dirs()
        return ds[-1][0] if ds else None

    def save(self, step: int, tree, extra=None):
        path = os.path.join(self.dir, f"step_{step}")
        save(path, tree, extra=dict(extra or {}, step=step))
        for s, d in self._step_dirs()[: -self.keep]:
            shutil.rmtree(d, ignore_errors=True)
        return path

    def restore_latest(self, target_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        tree, extra = restore(
            os.path.join(self.dir, f"step_{step}"), target_tree, shardings=shardings
        )
        return tree, extra
