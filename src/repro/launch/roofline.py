"""Roofline term derivation from compiled dry-run artifacts.

TPU v5e hardware constants (per assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

``compiled.cost_analysis()`` of an SPMD-partitioned module reports the
*per-device* program, so:
  compute term    = per_dev_FLOPs / peak            (== global/(chips*peak))
  memory term     = per_dev_bytes / hbm_bw
  collective term = per_dev_collective_bytes / link_bw
Collective bytes are not in cost_analysis; we parse the post-SPMD HLO text
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.  %x = bf16[16,4096,512]{2,1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[\w\[\]{},: ]*?(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand sizes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if kind + "-done(" in line:
            continue  # operands of -done are the -start token, skip double count
        # operands are inside the call parens: take shapes after the op name
        call = line[m.end() - 1:]
        total = 0
        for dt, dims in _SHAPE_RE.findall(call):
            total += _shape_bytes(dt, dims)
        out[kind] += total
    return out


def roofline_terms(acc: dict) -> dict:
    """acc: output of repro.launch.hlo_cost.analyze (per-device program).

    Primary terms use the bf16-equivalent byte counts (the CPU backend
    float-normalizes bf16 to f32; see hlo_cost); raw counts are also kept.
    """
    flops = float(acc.get("flops", 0.0))
    byt_raw = float(acc.get("bytes", 0.0))
    byt = float(acc.get("bytes_adj", byt_raw))
    coll = acc.get("collectives", {})
    coll_raw = float(sum(coll.values()))
    coll_total = float(acc.get("collectives_adj_total", coll_raw))
    compute_s = flops / PEAK_FLOPS
    memory_s = byt / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "bound_s": bound,
        "flops_per_dev": flops,
        "bytes_per_dev": byt,
        "bytes_per_dev_raw_f32": byt_raw,
        "collective_bytes_per_dev": coll_total,
        "collective_bytes_per_dev_raw_f32": coll_raw,
        "collective_breakdown": coll,
        # fraction of the step spent on the dominant term if perfectly overlapped
        "overlap_efficiency": bound / total if total > 0 else 0.0,
    }


def model_flops(cfg, shape: dict) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed this step."""
    n = cfg.param_count(active_only=True)
    kind = shape["kind"]
    if kind == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * shape["batch"]  # decode: one token per sequence
