import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402
import traceback # noqa: E402

import jax       # noqa: E402
import zstandard # noqa: E402

from repro.configs import ARCH_IDS, get_config                      # noqa: E402
from repro.launch.hlo_cost import analyze                           # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch.roofline import model_flops, roofline_terms       # noqa: E402
from repro.launch.steps import SHAPES, applicable_shapes, input_specs, rules_for, step_for  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, *, overrides=None, tag=""):
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path):
        print(f"[skip] {cell_id} (cached)", flush=True)
        return json.load(open(out_path))

    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        rules = rules_for(cfg, mesh, shape["kind"])
        step, donate = step_for(cfg, shape_name, rules)
        args = input_specs(cfg, shape_name, mesh, rules)

        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        # persist compressed HLO so terms can be re-derived without recompiling
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, cell_id + ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=3).compress(hlo.encode()))
        # trip-count-aware accounting (XLA cost_analysis visits while bodies
        # once; see launch/hlo_cost.py)
        acc = analyze(hlo)
        terms = roofline_terms(acc)
        mf = model_flops(cfg, shape)
        flops_global = terms["flops_per_dev"] * chips
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            },
            terms=terms,
            model_flops_global=mf,
            hlo_flops_global=flops_global,
            useful_flops_ratio=(mf / flops_global) if flops_global else 0.0,
            raw_cost_analysis={
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
            },
            hlo_bytes=len(hlo),
        )
        print(
            f"[ok] {cell_id}: compile={t_compile:.0f}s dominant={terms['dominant']} "
            f"bound={terms['bound_s']*1e3:.2f}ms useful={rec['useful_flops_ratio']:.2f}",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {cell_id}: {type(e).__name__}: {e}", flush=True)

    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES), help="single shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else applicable_shapes(cfg)
        for shape_name in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape_name, multi, args.out)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"done: {n_ok} ok, {n_fail} failed", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
