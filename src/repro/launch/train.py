"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 200 --batch 8 --seq 128

On a real TPU fleet the same entrypoint runs the full config on the
production mesh (--mesh single|multi); on CPU use --smoke (reduced config,
host mesh).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding.rules import make_rules
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = make_rules(mesh, "train", cfg.sharding_overrides.get("train"))

    tcfg = TrainConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        grad_compression=args.grad_compression,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
    )
    data = iter(TokenPipeline(cfg.vocab_size, args.seq, args.batch,
                              d_model=cfg.d_model, embed_inputs=cfg.embed_inputs,
                              mrope=cfg.mrope))
    with mesh:
        tr = Trainer(cfg, tcfg, mesh=mesh, rules=rules)
        state, hist = tr.run(data)
    for h in hist:
        print(f"step {h['step']:6d} loss {h['loss']:.4f} gnorm {h['grad_norm']:.3f}")
    print(f"done: {tr.step} steps, arch={cfg.name}, devices={len(jax.devices())}")


if __name__ == "__main__":
    main()
