"""jit-able train / prefill / serve steps + ShapeDtypeStruct input specs.

``input_specs`` follows the dry-run pattern: weak-type-correct, shardable
stand-ins with NamedShardings attached — no device allocation ever happens
for the full-size configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Ctx, cache_specs, decode_step, loss_fn, model_specs, prefill
from repro.models.config import ModelConfig
from repro.models.params import shape_dtypes, shardings as spec_shardings
from repro.sharding.rules import ShardingRules, make_rules
from repro.train.optimizer import AdamWConfig, AdamWState, init as adamw_init, update as adamw_update

# The assigned input-shape sets (LM family): seq_len x global_batch.
SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}


def rules_for(cfg: ModelConfig, mesh: Mesh, kind: str) -> ShardingRules:
    mode = "train" if kind == "train" else "serve"
    overrides = dict(cfg.sharding_overrides.get(mode, {}))
    if kind == "prefill" and cfg.ssm is None and cfg.rglru is None:
        # sequence-parallel residuals at layer boundaries: turns the TP
        # activation all-reduces into reduce-scatter(+all-gather) and runs
        # the inter-block elementwise work 16-way sharded.  Decode cannot
        # (S=1) and recurrent mixers need the full sequence per layer (SP
        # measured -3.6% there), so this applies to attention-only prefill
        # (§Perf iteration 9: yi -3.5%, qwen3 -3.1%).
        overrides.setdefault("act_seq_sp", "model")
    return make_rules(mesh, mode, overrides)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, rules: Optional[ShardingRules], opt_cfg: AdamWConfig):
    ctx = Ctx(cfg=cfg, rules=rules, mode="train")

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(ctx, p, batch), has_aux=True
        )(params)
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules]):
    ctx = Ctx(cfg=cfg, rules=rules, mode="prefill")

    def prefill_step(params, batch):
        return prefill(ctx, params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: Optional[ShardingRules]):
    ctx = Ctx(cfg=cfg, rules=rules, mode="decode")

    def serve_step(params, cache, batch):
        return decode_step(ctx, params, cache, batch)

    return serve_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def _sds(shape, dtype, mesh, rules, axes):
    if mesh is None or rules is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=rules.fitted_sharding(mesh, axes, shape))


def batch_specs(cfg: ModelConfig, mesh, rules, *, batch: int, seq: int, kind: str):
    """Model-input stand-ins for one step kind."""
    s = 1 if kind == "decode" else seq
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = _sds((batch, s), jnp.int32, mesh, rules, ("batch", "act_seq"))
    else:
        out["embeddings"] = _sds(
            (batch, s, cfg.d_model), jnp.bfloat16, mesh, rules, ("batch", "act_seq", "act_embed")
        )
    if kind != "decode":
        # positions are a runtime input (not arange constants) so attention
        # masks are data-dependent and XLA cannot hoist them out of kv scans
        if cfg.mrope:
            out["positions"] = _sds((batch, 3, s), jnp.int32, mesh, rules, ("batch", None, "act_seq"))
        else:
            out["positions"] = _sds((batch, s), jnp.int32, mesh, rules, ("batch", "act_seq"))
    elif cfg.mrope:
        out["positions"] = _sds((batch, 3, s), jnp.int32, mesh, rules, ("batch", None, "act_seq"))
    if kind == "train":
        out["labels"] = _sds((batch, s), jnp.int32, mesh, rules, ("batch", "act_seq"))
    return out


def params_specs(cfg: ModelConfig, mesh, rules, *, kind: str):
    serve = kind != "train"
    tree = model_specs(cfg, serve=serve)
    dtype = jnp.bfloat16 if serve else None  # serve float weights in bf16
    if mesh is None:
        return shape_dtypes(tree, dtype_override=dtype)
    sh = spec_shardings(tree, mesh, rules)
    return shape_dtypes(tree, dtype_override=dtype, shardings=sh)


def cache_input_specs(cfg: ModelConfig, mesh, rules, *, batch: int, seq: int):
    # the assigned decode shapes specify a KV cache of EXACTLY seq_len; the
    # serving headroom (append slots) is a runtime concern, zeroed here
    cfg0 = dataclasses.replace(cfg, decode_headroom=0)
    tree = cache_specs(cfg0, batch, seq)
    if mesh is None:
        return shape_dtypes(tree)
    sh = spec_shardings(tree, mesh, rules)
    return shape_dtypes(tree, shardings=sh)


def opt_state_specs(params_tree):
    zeros = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=getattr(p, "sharding", None)),
        params_tree,
    )
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return AdamWState(step=step, mu=zeros, nu=jax.tree_util.tree_map(lambda x: x, zeros))


def input_specs(cfg: ModelConfig, shape_name: str, mesh=None, rules=None):
    """Full argument spec tuple for the step that `shape_name` lowers."""
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    if rules is None and mesh is not None:
        rules = rules_for(cfg, mesh, kind)
    p = params_specs(cfg, mesh, rules, kind=kind)
    b = batch_specs(cfg, mesh, rules, batch=sh["batch"], seq=sh["seq"], kind=kind)
    if kind == "train":
        return (p, opt_state_specs(p), b)
    if kind == "prefill":
        return (p, b)
    c = cache_input_specs(cfg, mesh, rules, batch=sh["batch"], seq=sh["seq"])
    return (p, c, b)


def step_for(cfg: ModelConfig, shape_name: str, rules, opt_cfg: Optional[AdamWConfig] = None):
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return make_train_step(cfg, rules, opt_cfg or AdamWConfig()), (0, 1)
    if kind == "prefill":
        return make_prefill_step(cfg, rules), ()
    return make_serve_step(cfg, rules), (1,)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
