"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="results/dryrun", mesh="single", tag=""):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        rows.append(r)
    return rows


def markdown_table(out_dir="results/dryrun", mesh="single", tag=""):
    rows = load(out_dir, mesh, tag)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | "
        "MODEL/HLO flops | bound (ms) |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAILED | — | — |")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.1f} | "
            f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | "
            f"{t['dominant']} | {r['useful_flops_ratio']:.2f} | {t['bound_s']*1e3:.1f} |"
        )
    return "\n".join(lines)


def memory_table(out_dir="results/dryrun", mesh="single"):
    rows = load(out_dir, mesh)
    lines = [
        "| arch | shape | args (GB) | output (GB) | temp (GB) | fits 16 GB (TPU-adj) |",
        "|---|---|---:|---:|---:|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            continue
        m = r.get("memory", {})
        arg = m.get("argument_size_in_bytes", 0) / 1e9
        out = m.get("output_size_in_bytes", 0) / 1e9
        tmp = m.get("temp_size_in_bytes", 0) / 1e9
        # CPU float-normalization roughly doubles bf16 temporaries; donation
        # (unsupported on CPU) double-counts in/out.  TPU-adjusted estimate:
        adj = arg + tmp / 2
        lines.append(
            f"| {r['arch']} | {r['shape']} | {arg:.1f} | {out:.1f} | {tmp:.1f} | "
            f"{'yes' if adj <= 16.0 else 'NO'} ({adj:.1f}) |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(markdown_table(mesh=mesh))
    print()
    print(memory_table(mesh=mesh))
