"""Trip-count-aware cost accounting over optimized (post-SPMD) HLO text.

XLA's ``HloCostAnalysis`` visits ``while`` bodies exactly once, so any model
compiled with scan-over-layers under-reports FLOPs/bytes by ~num_layers.
The compiled HLO text carries ``backend_config={"known_trip_count":{"n":..}}``
on every while op, which lets us do exact loop-aware accounting:

  flops       : dot/convolution ops (2*prod(result)*K from contracting dims);
                elementwise flops outside dots are ignored (<~5% for these
                models — noted in EXPERIMENTS.md)
  hbm bytes   : per materialized instruction, operand+result bytes; fused
                computations count only their top-level operands/results
                (post-fusion instruction stream ~= HBM traffic); in-place
                dynamic-(update-)slice/gather count slice-sized traffic
  collectives : operand bytes per collective op kind

All counts are multiplied up the while-loop nesting chain by trip counts.
Operands are printed as bare %names in optimized dumps, so shapes resolve
through a module-wide symbol table (XLA uniquifies instruction names).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call", "opt-barrier",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)
_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=(?:\{)?%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_PARAM_RE = re.compile(
    r"([\w.\-]+):\s*(\((?:[^()]*)\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)"
)


def _shape_bytes_elems(text: str):
    """Returns (bytes, elems, bf16-equivalent bytes).

    The CPU backend float-normalizes bf16 compute to f32, so buffers that
    would be bf16 on TPU are stored/transferred as f32 in this HLO.  The
    bf16-equivalent metric counts f32 arrays at 2 B/elem to undo that
    artifact (legit-f32 small buffers — optimizer scalars, softmax stats —
    are a minor undercount; both metrics are reported).
    """
    b = e = badj = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b += n * _DTYPE_BYTES[dt]
        badj += n * (2 if dt == "f32" else _DTYPE_BYTES[dt])
        e += n
    return b, e, badj


@dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    call_text: str
    attr_text: str
    is_root: bool


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    root_opcode: str = ""


def parse_hlo(text: str):
    comps: Dict[str, Computation] = {}
    syms: Dict[str, str] = {}  # instruction/param name -> result shape text
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                for pname, pshape in _PARAM_RE.findall(m.group(2)):
                    syms[pname] = pshape
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        opcode = om.group(1)
        result_text = rest[: om.start()]
        depth = 0
        start = om.end() - 1
        end = start
        for i in range(start, len(rest)):
            c = rest[i]
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        call_text = rest[start + 1 : end]
        attr_text = rest[end + 1 :]
        is_root = line.lstrip().startswith("ROOT")
        instr = Instr(name, opcode, result_text, call_text, attr_text, is_root)
        cur.instrs.append(instr)
        syms[name] = result_text
        if is_root:
            cur.root_opcode = opcode
    return comps, syms


class HloCost:
    def __init__(self, text: str):
        self.comps, self.syms = parse_hlo(text)
        self.entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line)
                if m:
                    self.entry = m.group(1)
                break

    # -- shape helpers ------------------------------------------------------
    def _operand_names(self, instr: Instr):
        return _OPERAND_RE.findall(instr.call_text)

    def _operand_bytes(self, instr: Instr):
        """List of (raw, bf16-equivalent) byte pairs."""
        out = []
        for nm in self._operand_names(instr):
            b, _, badj = _shape_bytes_elems(self.syms.get(nm, ""))
            out.append((b, badj))
        return out

    def _result_bytes(self, instr: Instr):
        b, _, badj = _shape_bytes_elems(instr.result_text)
        return b, badj

    def _dot_flops(self, instr: Instr) -> float:
        ops = self._operand_names(instr)
        if not ops:
            return 0.0
        lhs_shape = self.syms.get(ops[0], "")
        mm = _SHAPE_RE.search(lhs_shape)
        if not mm:
            return 0.0
        lhs_dims = mm.group(2).split(",") if mm.group(2) else []
        m = _CONTRACT_RE.search(instr.attr_text)
        k = 1
        if m and m.group(1):
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= int(lhs_dims[i])
        _, result_elems, _ = _shape_bytes_elems(instr.result_text)
        return 2.0 * max(result_elems, 1) * k

    def _fusion_effective(self, comp: "Computation"):
        """(effective_root, sliced_param_bytes): unwrap convert/bitcast/copy
        chains at the root, and find parameters that are only consumed via
        dynamic-slice/gather inside the fusion (they stream slice-sized, not
        full-sized — matching TPU in-place/windowed behavior)."""
        by_name = {i.name: i for i in comp.instrs}
        root = None
        for i in comp.instrs:
            if i.is_root:
                root = i
                break
        eff = root.opcode if root else ""
        seen = 0
        while root is not None and root.opcode in ("convert", "bitcast", "copy", "transpose") and seen < 6:
            ops = _OPERAND_RE.findall(root.call_text)
            nxt = by_name.get(ops[0]) if ops else None
            if nxt is None:
                break
            root = nxt
            eff = root.opcode
            seen += 1
        # params read via slicing ops only
        param_uses: Dict[str, list] = {}
        for i in comp.instrs:
            for nm in _OPERAND_RE.findall(i.call_text):
                if nm in by_name and by_name[nm].opcode == "parameter":
                    param_uses.setdefault(nm, []).append(i)
        sliced: Dict[str, tuple] = {}
        for pname, users in param_uses.items():
            if users and all(u.opcode in ("dynamic-slice", "gather") for u in users):
                b = a = 0
                for u in users:
                    rb, _, ra = _shape_bytes_elems(u.result_text)
                    b += rb
                    a += ra
                pb, _, _ = _shape_bytes_elems(self.syms.get(pname, ""))
                if pb > 4 * max(b, 1):  # genuinely windowed read
                    sliced[pname] = (b, a)
        # map param name -> operand position: parameter(k) index in call text
        indexed = {}
        for i in comp.instrs:
            if i.opcode == "parameter":
                try:
                    indexed[int(i.call_text)] = i.name
                except ValueError:
                    pass
        return eff, sliced, indexed

    def _instr_bytes(self, instr: Instr):
        op = instr.opcode
        if op in _SKIP_BYTES:
            return 0.0, 0.0
        result_b, result_adj = self._result_bytes(instr)
        root = op
        sliced_params: Dict[int, tuple] = {}
        if op == "fusion":
            m = _CALLS_RE.search(instr.attr_text)
            if m and m.group(1) in self.comps:
                comp = self.comps[m.group(1)]
                eff, sliced, indexed = self._fusion_effective(comp)
                root = eff or comp.root_opcode or "fusion"
                for idx, pname in indexed.items():
                    if pname in sliced:
                        sliced_params[idx] = sliced[pname]
        opb = self._operand_bytes(instr)
        # apply slice-sized accounting for windowed parameter reads
        opb = [
            sliced_params.get(i, pair) for i, pair in enumerate(opb)
        ]
        if root in ("dynamic-update-slice", "scatter"):
            # in-place update: traffic = read update + write slice; operands
            # within 4x of the result are aliased full buffers, not traffic
            small = [p for p in opb if p[0] <= max(result_b, 1) / 4]
            if not small and opb:
                small = [min(opb)]
            return (
                float(2 * sum(b for b, _ in small)),
                float(2 * sum(a for _, a in small)),
            )
        if root in ("dynamic-slice", "gather"):
            small_r = sum(b for b, _ in opb if b <= max(result_b, 1))
            small_a = sum(a for b, a in opb if b <= max(result_b, 1))
            return float(2 * result_b + small_r), float(2 * result_adj + small_a)
        return (
            float(result_b + sum(b for b, _ in opb)),
            float(result_adj + sum(a for _, a in opb)),
        )

    # -- main recursion -----------------------------------------------------
    def totals(self) -> dict:
        memo: Dict[str, dict] = {}

        def total(comp_name: str) -> dict:
            if comp_name in memo:
                return memo[comp_name]
            acc = {"flops": 0.0, "bytes": 0.0, "bytes_adj": 0.0, "coll_adj": 0.0,
                   "coll": {k: 0.0 for k in _COLLECTIVES}}
            memo[comp_name] = acc
            comp = self.comps.get(comp_name)
            if comp is None:
                return acc

            def merge(t, mult=1):
                acc["flops"] += mult * t["flops"]
                acc["bytes"] += mult * t["bytes"]
                acc["bytes_adj"] += mult * t["bytes_adj"]
                acc["coll_adj"] += mult * t["coll_adj"]
                for k in _COLLECTIVES:
                    acc["coll"][k] += mult * t["coll"][k]

            for ins in comp.instrs:
                base = ins.opcode.replace("-start", "").replace("-done", "")
                if base in _COLLECTIVES:
                    if not ins.opcode.endswith("-done"):
                        opb = self._operand_bytes(ins)
                        ob = sum(b for b, _ in opb)
                        oa = sum(a for _, a in opb)
                        rb, ra = self._result_bytes(ins)
                        acc["coll"][base] += ob
                        acc["coll_adj"] += oa
                        acc["bytes"] += ob + rb
                        acc["bytes_adj"] += oa + ra
                    continue
                if ins.opcode == "while":
                    mb = _BODY_RE.search(ins.attr_text)
                    mc = _COND_RE.search(ins.attr_text)
                    mt = _TRIP_RE.search(ins.attr_text)
                    trip = int(mt.group(1)) if mt else 1
                    for sub in filter(None, [mb and mb.group(1), mc and mc.group(1)]):
                        merge(total(sub), trip)
                    continue
                if ins.opcode in ("call", "conditional", "async-start"):
                    for sub in _CALLS_RE.findall(ins.attr_text):
                        merge(total(sub))
                    continue
                if ins.opcode in ("dot", "convolution"):
                    acc["flops"] += self._dot_flops(ins)
                elif ins.opcode == "fusion":
                    m = _CALLS_RE.search(ins.attr_text)
                    if m and m.group(1) in self.comps:
                        for sub_ins in self.comps[m.group(1)].instrs:
                            if sub_ins.opcode in ("dot", "convolution"):
                                acc["flops"] += self._dot_flops(sub_ins)
                rb, ra = self._instr_bytes(ins)
                acc["bytes"] += rb
                acc["bytes_adj"] += ra
            return acc

        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "bytes_adj": 0.0, "coll_adj": 0.0, "coll": {}}
        return total(self.entry)


def analyze(text: str) -> dict:
    hc = HloCost(text)
    t = hc.totals()
    return {
        "flops": t["flops"],
        "bytes": t["bytes"],
        "bytes_adj": t["bytes_adj"],
        "collectives_adj_total": t["coll_adj"],
        "collectives": {k: v for k, v in t["coll"].items() if v},
        "n_computations": len(hc.comps),
    }


def top_contributors(text: str, n: int = 25, kind: str = "bytes"):
    """Debug view: heaviest instructions (bytes or flops) including the
    while-loop multiplicity of their computation."""
    hc = HloCost(text)
    # multiplicity per computation via one pass over while ops
    mult: Dict[str, float] = {}

    def walk(comp_name: str, m: float):
        mult[comp_name] = mult.get(comp_name, 0.0) + m
        comp = hc.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                mb = _BODY_RE.search(ins.attr_text)
                mc = _COND_RE.search(ins.attr_text)
                mt = _TRIP_RE.search(ins.attr_text)
                trip = int(mt.group(1)) if mt else 1
                for sub in filter(None, [mb and mb.group(1), mc and mc.group(1)]):
                    walk(sub, m * trip)
            elif ins.opcode in ("call", "conditional"):
                for sub in _CALLS_RE.findall(ins.attr_text):
                    walk(sub, m)

    if hc.entry:
        walk(hc.entry, 1.0)
    rows = []
    for cname, m in mult.items():
        comp = hc.comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if kind == "bytes":
                val, _ = hc._instr_bytes(ins)
            else:
                val = hc._dot_flops(ins) if ins.opcode in ("dot", "convolution") else 0.0
            if val:
                rows.append((val * m, m, cname, ins.opcode, ins.name,
                             ins.result_text.strip()[:60]))
    rows.sort(reverse=True)
    return rows[:n]
