"""Serving launcher: R2E-VID routed inference over live edge/cloud pools.

  PYTHONPATH=src python -m repro.launch.serve --rounds 4 --streams 8

Video streams are synthesized, motion features drive the temporal gate, and
one :class:`~repro.serving.session.ServeSession` owns the whole serving
stack: the gate-mode ``r2evid`` policy (RouterState carry threaded through
the compiled, donated decide scan), the config bundle, and the live tier
pools the routed token workloads dispatch onto (``session.dispatch``).

Each round consumes ``--segments-per-round`` segments per stream in ONE
compiled ``lax.scan`` (``session.route_many``): the gate recurrence carries
across segments and rounds (no window re-scan, no per-segment Python
dispatch, carry buffers donated — never copied), and the last segment's
solution drives the round's dispatch.  ``--policy`` swaps in any registered
policy (baselines route the same loop; they simply ignore the features).
``--gate-resync`` sets the cadence at which the batched gate recomputes its
running volatility sums from the exact ring buffer (0 = once per window;
1 = every step, drift-free).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cost_model import SystemConfig
from repro.core.features import feature_dim, segment_features
from repro.core.gating import GateConfig, gate_specs
from repro.data.video import VideoConfig, generate_stream, make_task_batch
from repro.models.params import init_params
from repro.serving.policy import make_policy
from repro.serving.pools import make_tier_pools
from repro.serving.session import ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--segments-per-round", type=int, default=8)
    ap.add_argument("--edge-arch", default="qwen1.5-0.5b")
    ap.add_argument("--cloud-arch", default="qwen3-8b")
    ap.add_argument("--policy", default="r2evid",
                    help="registered policy name (r2evid, a2_cloud_only, "
                         "jcab, rdap, sniper)")
    ap.add_argument("--gate-resync", type=int, default=0,
                    help="volatility resync cadence in steps (0 = per window)")
    args = ap.parse_args()

    sys_ = SystemConfig()
    if args.policy == "r2evid":
        gcfg = GateConfig(d_feature=feature_dim(), resync_period=args.gate_resync)
        gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
        policy = make_policy("r2evid", sys_, gate_cfg=gcfg, gate_params=gparams)
    else:
        policy = make_policy(args.policy, sys_)
    session = ServeSession(
        policy, n_streams=args.streams,
        pools=make_tier_pools(get_smoke_config(args.edge_arch),
                              get_smoke_config(args.cloud_arch)),
    )

    spr = args.segments_per_round
    vcfg = VideoConfig()
    streams = [generate_stream(vcfg, n_segments=args.rounds * spr, rng=np.random.default_rng(i))
               for i in range(args.streams)]
    aq = jnp.asarray(make_task_batch(args.streams, "stable"))
    # (streams, total_segments, d) segment features, computed once per stream
    dx_all = jnp.stack([
        segment_features(jnp.asarray(fr), vcfg.frames_per_segment)
        for fr, _ in streams
    ])

    for rnd in range(args.rounds):
        z = jnp.asarray([m[rnd * spr:(rnd + 1) * spr].mean() for _, m in streams])
        t_route = time.perf_counter()
        # stream this round's segments through the session in one lax.scan
        dx_seq = jnp.swapaxes(dx_all[:, rnd * spr:(rnd + 1) * spr], 0, 1)
        sols = session.route_many(dx_seq, z, aq)
        sol = jax.tree_util.tree_map(lambda x: x[-1], sols)
        jax.block_until_ready(sol["route"])
        route_ms = (time.perf_counter() - t_route) * 1e3

        t0 = time.perf_counter()
        served = session.dispatch(sol)
        dt = time.perf_counter() - t0
        taus = sol.get("tau")
        print(f"round {rnd}: routes={np.asarray(sol['route']).tolist()} "
              + (f"taus={np.round(np.asarray(taus), 2).tolist()} "
                 if taus is not None else "")
              + f"route={route_ms:.0f}ms serve={dt*1e3:.0f}ms")
        for tier, st in sorted(served.items()):
            print(f"  tier{tier}: {st['requests']} req "
                  f"{st['tokens_per_s']:.0f} tok/s "
                  f"p50={st['p50_s']*1e3:.0f}ms p99={st['p99_s']*1e3:.0f}ms")

    fb = session.feedback()
    print(f"feedback: bw_mult={np.round(np.asarray(fb['bw_mult']), 3).tolist()}"
          f" (apply_feedback folds this into the next round's observation)")
    for tier, pool in session.pools.items():
        s = pool.stats.summary()
        print(f"pool[{pool.name}]: requests={s['requests']} "
              f"tokens={s['tokens']} busy={s['busy_s']:.2f}s "
              f"throughput={s['tokens_per_s']:.0f} tok/s "
              f"p50={s['p50_s']*1e3:.0f}ms p99={s['p99_s']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
