"""Serving launcher: R2E-VID routed inference over live edge/cloud pools.

  PYTHONPATH=src python -m repro.launch.serve --rounds 4 --streams 8

Video streams are synthesized, motion features drive the temporal gate, and
the *streaming* router engine (RouterState threaded through the jit-compiled
``route_step``) assigns (route, r, p, v) per segment; token workloads
(proportional to the chosen fidelity) are executed on real model pools.

Each round consumes ``--segments-per-round`` segments per stream in ONE
compiled ``lax.scan`` (``RouterEngine.step_many``): the gate recurrence
carries across segments and rounds (no window re-scan, no per-segment Python
dispatch, carry buffers donated — never copied), and the last segment's
solution drives the round's dispatch.  ``--gate-resync`` sets the cadence at
which the batched gate recomputes its running volatility sums from the exact
ring buffer (0 = once per window; 1 = every step, drift-free).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cost_model import SystemConfig
from repro.core.features import feature_dim, segment_features
from repro.core.gating import GateConfig, gate_specs
from repro.core.robust import RobustProblem
from repro.core.router import RouterEngine
from repro.data.video import VideoConfig, generate_stream, make_task_batch
from repro.models.params import init_params
from repro.serving.pools import make_tier_pools


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--segments-per-round", type=int, default=8)
    ap.add_argument("--edge-arch", default="qwen1.5-0.5b")
    ap.add_argument("--cloud-arch", default="qwen3-8b")
    ap.add_argument("--gate-resync", type=int, default=0,
                    help="volatility resync cadence in steps (0 = per window)")
    args = ap.parse_args()

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    gcfg = GateConfig(d_feature=feature_dim(), resync_period=args.gate_resync)
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    pools = make_tier_pools(get_smoke_config(args.edge_arch), get_smoke_config(args.cloud_arch))

    spr = args.segments_per_round
    vcfg = VideoConfig()
    streams = [generate_stream(vcfg, n_segments=args.rounds * spr, rng=np.random.default_rng(i))
               for i in range(args.streams)]
    aq = jnp.asarray(make_task_batch(args.streams, "stable"))
    # (streams, total_segments, d) segment features, computed once per stream
    dx_all = jnp.stack([
        segment_features(jnp.asarray(fr), vcfg.frames_per_segment)
        for fr, _ in streams
    ])

    engine = RouterEngine(prob, gcfg, gparams, n_streams=args.streams)

    for rnd in range(args.rounds):
        z = jnp.asarray([m[rnd * spr:(rnd + 1) * spr].mean() for _, m in streams])
        t_route = time.perf_counter()
        # stream this round's segments through the engine in one lax.scan
        dx_seq = jnp.swapaxes(dx_all[:, rnd * spr:(rnd + 1) * spr], 0, 1)
        sols = engine.step_many(dx_seq, z, aq)
        sol = jax.tree_util.tree_map(lambda x: x[-1], sols)
        jax.block_until_ready(sol["route"])
        route_ms = (time.perf_counter() - t_route) * 1e3

        t0 = time.perf_counter()
        for tier in (0, 1):
            idx = np.where(np.asarray(sol["route"]) == tier)[0]
            if len(idx) == 0:
                continue
            # token budget scales with chosen fidelity (resolution x fps)
            n_tok = 16 * (1 + int(np.asarray(sol["r"])[idx].mean()))
            toks = jnp.ones((len(idx), n_tok), jnp.int32)
            pools[tier].serve_segment(toks)
        dt = time.perf_counter() - t0
        print(f"round {rnd}: routes={np.asarray(sol['route']).tolist()} "
              f"taus={np.round(np.asarray(sol['tau']), 2).tolist()} "
              f"route={route_ms:.0f}ms serve={dt*1e3:.0f}ms")

    for tier, pool in pools.items():
        s = pool.stats
        tps = s.tokens / max(s.busy_s, 1e-9)
        print(f"pool[{pool.name}]: requests={s.requests} tokens={s.tokens} "
              f"busy={s.busy_s:.2f}s throughput={tps:.0f} tok/s")


if __name__ == "__main__":
    main()
