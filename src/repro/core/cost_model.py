"""Delay / energy / accuracy model (paper §3.1, §4.1.2).

Knobs (exactly the paper's): resolutions {360,540,720,900,1080}p, frame rates
10–50 FPS, K=5 model versions per tier, cloud model ~10x the edge model,
bandwidths 100/50 Mbps, powers 100/15 W, cost = D + β·E with β = 0.06.

Two hardware profiles:
  "paper"  : Jetson-NX edge + Xeon cloud throughputs (reproduction)
  "tpu_v5e": edge/cloud = small/large TPU v5e pools; per-version throughput is
             derived from the dry-run roofline terms of the variant ladder
             (hardware adaptation, DESIGN.md §2)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    resolutions: tuple = (360, 540, 720, 900, 1080)      # p
    fps_options: tuple = (10, 20, 30, 40, 50)
    num_versions: int = 5
    beta: float = 0.06
    segment_sec: float = 1.0
    bits_per_pixel: float = 0.12          # H.264-ish compressed
    edge_bw_mbps: float = 50.0
    cloud_bw_mbps: float = 100.0
    edge_power_w: float = 15.0
    cloud_power_w: float = 100.0
    transmit_power_w: float = 2.5
    # per-tier sustained throughput in GFLOP/s (paper profile)
    edge_gflops: float = 800.0            # Jetson Xavier NX effective
    cloud_gflops: float = 6000.0          # Xeon 4214R effective
    # version ladder: FLOPs per frame at 1080p, edge tier (GFLOP)
    v1_gflops_per_frame: float = 1.2      # YOLOv5n-ish
    version_scale: float = 1.9            # v_{k+1} = scale * v_k
    cloud_model_factor: float = 10.0      # cloud models ~10x edge (paper §4.1.1)
    total_bw_mbps: float = 600.0          # C6 budget across tasks
    gamma: int = 2                        # Γ uncertainty budget
    u_dev: float = 0.35                   # max relative deviation ũ_k
    acc_margin_nominal: float = 0.005     # baselines' feasibility slack
    acc_margin_robust: float = 0.02       # ours: robustly protected C1

    @property
    def n_res(self):
        return len(self.resolutions)

    @property
    def n_fps(self):
        return len(self.fps_options)


def _pixels(res_p):
    return (res_p * 16 // 9) * res_p


def version_flops(sys: SystemConfig, tier: int, k: int, res_p: int) -> float:
    """GFLOP per frame for version k (0-based) on tier (0=edge, 1=cloud)."""
    base = sys.v1_gflops_per_frame * (sys.version_scale ** k)
    if tier == 1:
        base *= sys.cloud_model_factor
    return base * _pixels(res_p) / _pixels(1080)


# ---------------------------------------------------------------------------
# Vectorized tables over the full decision lattice
# ---------------------------------------------------------------------------
def res_norm(sys: SystemConfig) -> jnp.ndarray:
    """(N,) resolutions normalized by the 1080p reference — the accuracy
    formula's r coordinate.  Single source of the normalization: every
    accuracy path (broadcast table, pointwise gathers, Stage-1 slice, the
    lattice's flat coordinate vectors) divides the same float32 values by
    the same constant, which is what keeps them bitwise interchangeable."""
    return jnp.asarray(sys.resolutions, jnp.float32) / 1080.0


def fps_norm(sys: SystemConfig) -> jnp.ndarray:
    """(Z,) frame rates normalized by the 50-FPS reference — the accuracy
    formula's p coordinate (same single-source contract as res_norm)."""
    return jnp.asarray(sys.fps_options, jnp.float32) / 50.0


def _accuracy_formula(z, r, p, k, tier):
    """Shared accuracy surface f(r, p, v, tier | z) — single source of truth
    for the broadcast table and the pointwise gather (elementwise ops in the
    same order, so both evaluations agree bitwise).  r/p are normalized to
    [0, 1]; k/tier are float indices."""
    a_max = 0.60 + 0.045 * k + 0.04 * tier           # bigger model, higher ceiling
    sat = 1.0 - jnp.exp(-(2.5 + 0.3 * k) * r)
    f = a_max * sat
    f = f - 0.10 * z * (1.0 - p) - 0.06 * z * (1.0 - r)
    return jnp.clip(f, 0.0, 1.0)


def accuracy_table(sys: SystemConfig, difficulty):
    """f(r, p, v, y | z): (..., N, Z, K, 2) accuracy for difficulty z (...,).

    Monotone saturating in resolution and version (paper Fig. 2 shape);
    difficulty z in [0,1] (content motion) penalizes low fps / low res.
    """
    z = jnp.asarray(difficulty)[..., None, None, None, None]
    r = res_norm(sys)
    p = fps_norm(sys)
    k = jnp.arange(sys.num_versions, dtype=jnp.float32)
    r = r[:, None, None, None]
    p = p[None, :, None, None]
    k = k[None, None, :, None]
    tier = jnp.arange(2, dtype=jnp.float32)[None, None, None, :]
    return _accuracy_formula(z, r, p, k, tier)


def accuracy_at(sys: SystemConfig, difficulty, r, p, v, route):
    """Accuracy at chosen (r, p, v, route) index arrays — the table formula
    evaluated only at the given configs: O(M) per task instead of the
    O(M·N·Z·K·2) broadcast table (the realization hot path gathers exactly
    one entry per task, so it never needs the table)."""
    z = jnp.asarray(difficulty)
    rn = res_norm(sys)[r]
    pn = fps_norm(sys)[p]
    return _accuracy_formula(z, rn, pn, v.astype(jnp.float32),
                             route.astype(jnp.float32))


def accuracy_stage1(sys: SystemConfig, difficulty):
    """(M, N) accuracy of the smallest model (v1) on edge at max fps — the
    ``f[:, :, -1, 0, 0]`` slice of :func:`accuracy_table`, evaluated pointwise
    so Stage-1 never builds the (M, N, Z, K, 2) table.  Same elementwise ops
    in the same order as the table, hence bitwise identical to the slice."""
    z = jnp.asarray(difficulty)[..., None]
    rn = res_norm(sys)
    pn = fps_norm(sys)[-1]
    zero = jnp.float32(0.0)
    return _accuracy_formula(z, rn, pn, zero, zero)


def cost_tables(sys: SystemConfig):
    """Returns (c1, b2, bw_mb):

      c1   : (N, Z, 2) first-stage cost  — transmission delay + β·tx energy
      b2   : (N, Z, K, 2) second-stage   — compute delay + β·compute energy
      bw_mb: (N, Z, 2) bandwidth consumed (Mbps) per config
    """
    res = np.array(sys.resolutions, np.float32)
    fps = np.array(sys.fps_options, np.float32)
    pix = np.array([_pixels(int(r)) for r in sys.resolutions], np.float32)

    data_mbit = (pix[:, None] * fps[None, :] * sys.segment_sec * sys.bits_per_pixel) / 1e6
    bw = np.array([sys.edge_bw_mbps, sys.cloud_bw_mbps], np.float32)
    trans_delay = data_mbit[..., None] / bw  # (N, Z, 2) seconds
    trans_energy = sys.transmit_power_w * trans_delay
    c1 = trans_delay + sys.beta * trans_energy

    gf = np.zeros((sys.n_res, sys.num_versions, 2), np.float32)
    for i, r in enumerate(sys.resolutions):
        for k in range(sys.num_versions):
            for t in range(2):
                gf[i, k, t] = version_flops(sys, t, k, int(r))
    thr = np.array([sys.edge_gflops, sys.cloud_gflops], np.float32)
    power = np.array([sys.edge_power_w, sys.cloud_power_w], np.float32)
    # frames processed per segment = fps * seg_sec
    comp_delay = (
        gf[:, None, :, :] * fps[None, :, None, None] * sys.segment_sec / thr
    )  # (N, Z, K, 2)
    comp_energy = power * comp_delay
    b2 = comp_delay + sys.beta * comp_energy

    return jnp.asarray(c1), jnp.asarray(b2), jnp.asarray(data_mbit[..., None] * np.ones(2))
