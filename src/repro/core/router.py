"""R2E-VID two-stage router (paper Alg. 1 + Alg. 2 glue).

Stage 1 (Alg. 1): the temporal gate scores each segment (τ_t); the adaptive
configuration picks the smallest resolution meeting the accuracy requirement
under the *smallest* model (f_i(r, v1) ≥ A^q), escalates to cloud when even
the largest edge config is infeasible, and enforces the temporal-consistency
constraint ‖y_t − y_{t−1}‖₁ ≤ δ(|τ_t − τ_{t−1}|).

Stage 2 (Alg. 2): the CCG robust optimizer refines (r, p, v, y) under the
Γ-budget uncertainty set, warm-started from Stage 1.

The bandwidth budget C6 (Σ B_i ≤ B) is enforced by a vectorized demotion
repair pass: tasks with the most bandwidth and most accuracy slack step down
fidelity until the budget holds.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables
from repro.core.gating import GateConfig, gate_scan_batch
from repro.core.robust import BIG, RobustProblem, solve_ccg


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    tau_cloud: float = 0.55       # Stage-1 warm-start cloud threshold
    delta0: float = 0.0           # temporal consistency: δ(x) = δ0 + δ1·x
    delta1: float = 4.0
    repair_rounds: int = 8        # C6 demotion passes


# ---------------------------------------------------------------------------
# Stage 1: adaptive edge-cloud configuration (Alg. 1)
# ---------------------------------------------------------------------------
def stage1_configure(sys: SystemConfig, taus, difficulty, acc_req, prev_route, prev_tau,
                     rcfg: RouterConfig = RouterConfig()):
    """Vectorized Alg. 1.  All inputs (M,).  Returns route, r_idx warm starts."""
    f = accuracy_table(sys, difficulty)                  # (M, N, Z, K, 2)
    # f_i(r, v1) at the max fps, per tier (Alg.1 line 3: guided by τ)
    f_edge_v1 = f[:, :, -1, 0, 0]                        # (M, N)
    feasible_edge = f_edge_v1 >= acc_req[:, None]
    # smallest feasible resolution on edge (Alg.1 lines 4-5)
    first_ok = jnp.argmax(feasible_edge, axis=1)
    any_ok = feasible_edge.any(axis=1)
    r_idx = jnp.where(any_ok, first_ok, sys.n_res - 1)
    # Alg.1 line 8: escalate to cloud while infeasible on edge
    route = jnp.where(any_ok, (taus > rcfg.tau_cloud).astype(jnp.int32), 1)
    # temporal consistency constraint (Eq. after (6)):
    # |y_t - y_{t-1}| <= δ(|τ_t - τ_{t-1}|); with binary y this means a route
    # FLIP is only allowed when the gate moved enough.
    allowed = (jnp.abs(taus - prev_tau) * rcfg.delta1 + rcfg.delta0) >= 1.0
    flip = route != prev_route
    route = jnp.where(flip & ~allowed & (prev_route >= 0), prev_route, route)
    return route, r_idx


# ---------------------------------------------------------------------------
# C6 bandwidth repair
# ---------------------------------------------------------------------------
def enforce_bandwidth(sys: SystemConfig, sol, difficulty, acc_req, total_budget=None,
                      rounds: int = 8):
    """Demote (r, p) of over-budget tasks with the largest bandwidth draw that
    remain feasible after demotion; fixed-round vectorized repair."""
    _, _, bw_tab = cost_tables(sys)                      # (N, Z, 2) Mbps
    f = accuracy_table(sys, difficulty)
    budget = sys.total_bw_mbps if total_budget is None else total_budget

    margin = sys.acc_margin_robust

    def round_fn(state, _):
        r, p = state
        bw = bw_tab[r, p, sol["route"]]
        over = bw.sum() > budget
        # candidate demotion: prefer dropping fps, then resolution
        p_dn = jnp.maximum(p - 1, 0)
        r_dn = jnp.maximum(r - 1, 0)
        f_pdn = f[jnp.arange(r.shape[0]), r, p_dn, sol["v"], sol["route"]]
        f_rdn = f[jnp.arange(r.shape[0]), r_dn, p, sol["v"], sol["route"]]
        can_p = (p > 0) & (f_pdn >= acc_req + margin)
        can_r = (r > 0) & (f_rdn >= acc_req + margin)
        gain_p = bw - bw_tab[r, p_dn, sol["route"]]
        gain_r = bw - bw_tab[r_dn, p, sol["route"]]
        gain = jnp.where(can_p, gain_p, jnp.where(can_r, gain_r, -BIG))
        pick = gain.argmax()
        do = over & (gain[pick] > 0)
        use_p = can_p[pick]
        r = r.at[pick].set(jnp.where(do & ~use_p, r_dn[pick], r[pick]))
        p = p.at[pick].set(jnp.where(do & use_p, p_dn[pick], p[pick]))
        return (r, p), bw.sum()

    (r, p), bw_hist = jax.lax.scan(round_fn, (sol["r"], sol["p"]), None, length=rounds)
    return dict(sol, r=r, p=p), bw_hist


# ---------------------------------------------------------------------------
# Full two-stage pipeline
# ---------------------------------------------------------------------------
def route(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    dx_segments,          # (M, T, d) motion features per stream segment window
    difficulty,           # (M,)
    acc_req,              # (M,)
    prev_route=None,      # (M,) previous segment's route (-1 = none)
    prev_tau=None,
    rcfg: RouterConfig = RouterConfig(),
):
    sys = prob.sys
    m = dx_segments.shape[0]
    if prev_route is None:
        prev_route = -jnp.ones((m,), jnp.int32)
    if prev_tau is None:
        prev_tau = jnp.zeros((m,))

    taus_seq, gates, _ = gate_scan_batch(gate_cfg, gate_params, dx_segments)
    taus = taus_seq[:, -1]

    warm_route, warm_r = stage1_configure(
        sys, taus, difficulty, acc_req, prev_route, prev_tau, rcfg
    )
    sol = solve_ccg(prob, difficulty, acc_req)
    # Stage-1 consistency overrides Stage-2 route flips that the gate forbids
    allowed = (jnp.abs(taus - prev_tau) * rcfg.delta1 + rcfg.delta0) >= 1.0
    flip = sol["route"] != prev_route
    had_prev = prev_route >= 0
    sol = dict(sol, route=jnp.where(flip & ~allowed & had_prev, prev_route, sol["route"]))
    sol, bw_hist = enforce_bandwidth(sys, sol, difficulty, acc_req, rounds=rcfg.repair_rounds)
    sol["tau"] = taus
    sol["warm_route"] = warm_route
    sol["warm_r"] = warm_r
    sol["bw_history"] = bw_hist
    return sol
