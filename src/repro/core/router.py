"""R2E-VID two-stage router (paper Alg. 1 + Alg. 2 glue) + streaming engine.

Stage 1 (Alg. 1): the temporal gate scores each segment (τ_t); the adaptive
configuration picks the smallest resolution meeting the accuracy requirement
under the *smallest* model (f_i(r, v1) ≥ A^q), escalates to cloud when even
the largest edge config is infeasible, and enforces the temporal-consistency
constraint ‖y_t − y_{t−1}‖₁ ≤ δ(|τ_t − τ_{t−1}|).

Stage 2 (Alg. 2): the CCG robust optimizer refines (r, p, v, y) under the
Γ-budget uncertainty set, warm-started from Stage 1.

The bandwidth budget C6 (Σ B_i ≤ B) is enforced by a vectorized demotion
repair pass: tasks with the most bandwidth and most accuracy slack step down
fidelity until the budget holds.

Two entry points:

  * :func:`route` — windowed, stateless: scans the gate over a whole
    (M, T, d) feature window each call.  Kept for offline planning and
    back-compat.
  * :class:`RouterState` + :func:`route_step` — the streaming engine.  The
    gate hidden state, ring buffer, and previous (route, τ) thread through a
    fully jit-compiled per-segment step, so multi-round serving touches each
    segment's features exactly once and never rebuilds tables.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig, accuracy_stage1, fps_norm, res_norm
from repro.core.gating import (
    GateBatchState,
    GateConfig,
    gate_step_batch,
    gate_window_scan,
    init_batch_state,
)
from repro.core.lattice import DecisionLattice
from repro.core.robust import RobustProblem, solve_ccg_fused
from repro.kernels.c6_tail.ops import c6_tail


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    tau_cloud: float = 0.55       # Stage-1 warm-start cloud threshold
    delta0: float = 0.0           # temporal consistency: δ(x) = δ0 + δ1·x
    delta1: float = 4.0
    repair_rounds: int = 8        # C6 demotion passes


def _as_lattice(sys_or_lat) -> DecisionLattice:
    if isinstance(sys_or_lat, DecisionLattice):
        return sys_or_lat
    return DecisionLattice.build(sys_or_lat)


def temporal_flip_allowed(taus, prev_tau, rcfg: RouterConfig):
    """Temporal-consistency constraint (Eq. after (6)): with binary y a route
    FLIP is only allowed when the gate moved enough: δ(|τ_t − τ_{t−1}|) ≥ 1."""
    return (jnp.abs(taus - prev_tau) * rcfg.delta1 + rcfg.delta0) >= 1.0


def apply_temporal_consistency(route, prev_route, taus, prev_tau, rcfg: RouterConfig):
    """Suppress forbidden flips; ``prev_route < 0`` means no history (allowed)."""
    allowed = temporal_flip_allowed(taus, prev_tau, rcfg)
    flip = route != prev_route
    return jnp.where(flip & ~allowed & (prev_route >= 0), prev_route, route)


def clamp_route_available(route, tier_ok):
    """Force routes off outaged tiers.  ``tier_ok``: (..., 2) availability
    (0 = edge, 1 = cloud; <= 0 means down).  Availability overrides every
    other constraint — including temporal consistency — so this runs LAST:
    a stream pinned to a dead tier by its history must still move."""
    route = jnp.where(tier_ok[..., 1] > 0, route, jnp.zeros_like(route))
    route = jnp.where(tier_ok[..., 0] > 0, route, jnp.ones_like(route))
    return route


# ---------------------------------------------------------------------------
# Stage 1: adaptive edge-cloud configuration (Alg. 1)
# ---------------------------------------------------------------------------
def stage1_configure(sys_or_lat, taus, difficulty, acc_req, prev_route, prev_tau,
                     rcfg: RouterConfig = RouterConfig(), tier_ok=None):
    """Vectorized Alg. 1.  All inputs (M,).  Returns route, r_idx warm starts.

    Table-free: the only accuracy values Alg. 1 consults are f_i(r, v1) on
    edge at max fps, so the shared formula is evaluated directly on that
    (M, N) slice (bitwise identical to slicing the broadcast table, which
    this path historically built and threw 99.6% of away).

    ``tier_ok``: optional (2,) tier availability — an outaged tier is never
    selected (the clamp runs after temporal consistency: survivors re-route
    even when their history would pin them to the dead tier).
    """
    sys = sys_or_lat.sys if isinstance(sys_or_lat, DecisionLattice) else sys_or_lat
    # f_i(r, v1) at the max fps, edge tier (Alg.1 line 3: guided by τ)
    f_edge_v1 = accuracy_stage1(sys, difficulty)         # (M, N)
    feasible_edge = f_edge_v1 >= acc_req[:, None]
    # smallest feasible resolution on edge (Alg.1 lines 4-5)
    first_ok = jnp.argmax(feasible_edge, axis=1)
    any_ok = feasible_edge.any(axis=1)
    r_idx = jnp.where(any_ok, first_ok, sys.n_res - 1)
    # Alg.1 line 8: escalate to cloud while infeasible on edge
    route = jnp.where(any_ok, (taus > rcfg.tau_cloud).astype(jnp.int32), 1)
    route = apply_temporal_consistency(route, prev_route, taus, prev_tau, rcfg)
    if tier_ok is not None:
        route = clamp_route_available(route, tier_ok)
    return route, r_idx


# ---------------------------------------------------------------------------
# C6 bandwidth repair
# ---------------------------------------------------------------------------
def enforce_bandwidth(sys_or_lat, sol, difficulty, acc_req, total_budget=None,
                      rounds: int = 8, force: str = "auto", task_mask=None):
    """Demote (r, p) of over-budget tasks with the largest bandwidth draw that
    remain feasible after demotion; fixed-round vectorized repair.

    ``task_mask``: optional (M,) bool alive mask (slot-pool churn).  Dead
    lanes contribute zero bandwidth to the budget sum and are never demoted
    (their reclaimable gain is zeroed), so the repair on a masked pool is
    exactly the repair on the compacted alive batch.

    Each round demotes the *top-k* largest-gain tasks at once — exactly the
    prefix (by descending gain) needed to clear the excess over the budget —
    instead of one scalar ``.at[pick].set`` demotion per round, so the repair
    converges in ~#fidelity-levels rounds independent of the batch size M.

    The per-task tail of each round — current draw, candidate-demotion
    accuracies, reclaimable gain — is the fused ``c6_tail`` kernel on the
    hoisted route-indexed (M, N·Z) bandwidth panel (bit-identical to the
    historical ``take_along_axis`` + ``accuracy_at`` body); only the global
    argsort/prefix choice stays here.  Rounds are self-terminating: once a
    round demotes nothing (or the budget holds), every later round is a
    deterministic no-op on the same (r, p), so the scan skips the tail work
    under a ``lax.cond`` and emits the bit-identical ``excess + budget``
    history entry.
    """
    lat = _as_lattice(sys_or_lat)
    sys = lat.sys
    budget = sys.total_bw_mbps if total_budget is None else total_budget

    m = sol["r"].shape[0]
    nz = sys.n_fps
    # C6 demotion never flips the route, so the per-task (N, Z) bandwidth
    # panel for its route is round-invariant: hoist the route gather out of
    # the scan body once, flat (r·Z + p)-indexed inside
    bw_panel = jnp.moveaxis(lat.bw, -1, 0)[sol["route"]]   # (M, N, Z)
    bw_panel = bw_panel.reshape(bw_panel.shape[0], -1)     # (M, N·Z)
    _take_bw = lambda r, p: jnp.take_along_axis(
        bw_panel, (r * nz + p)[:, None], axis=1)[:, 0]
    if task_mask is None:
        take_bw = _take_bw
    else:
        take_bw = lambda r, p: jnp.where(task_mask, _take_bw(r, p), 0.0)
    z = jnp.asarray(difficulty, jnp.float32)
    acc_thr = jnp.asarray(acc_req, jnp.float32) + sys.acc_margin_robust
    rn = res_norm(sys)
    pn = fps_norm(sys)

    def round_fn(state, _):
        r, p, active = state
        bw = take_bw(r, p)
        excess = bw.sum() - budget

        def demote_round(rp):
            r, p = rp
            _, gain, can_p = c6_tail(
                bw_panel, r, p, sol["v"], sol["route"], z, acc_thr, rn, pn,
                n_fps=nz, force=force)
            if task_mask is not None:
                gain = jnp.where(task_mask, gain, 0.0)
            p_dn = jnp.maximum(p - 1, 0)
            r_dn = jnp.maximum(r - 1, 0)
            # top-k demotion: in descending-gain order, demote tasks while the
            # cumulative reclaimed bandwidth is still short of the excess
            order = jnp.argsort(-gain)
            gain_sorted = gain[order]
            cum_before = jnp.concatenate(
                [jnp.zeros((1,), gain.dtype), jnp.cumsum(gain_sorted)[:-1]]
            )
            demote_sorted = (cum_before < excess) & (gain_sorted > 0)
            demote = jnp.zeros((m,), bool).at[order].set(demote_sorted)
            return (jnp.where(demote & ~can_p, r_dn, r),
                    jnp.where(demote & can_p, p_dn, p),
                    demote.any())

        def skip_round(rp):
            r, p = rp
            return r, p, jnp.asarray(False)

        r, p, progressed = jax.lax.cond(
            active & (excess > 0), demote_round, skip_round, (r, p))
        return (r, p, progressed), excess + budget

    (r, p, _), bw_hist = jax.lax.scan(
        round_fn, (sol["r"], sol["p"], jnp.asarray(True)), None, length=rounds)
    return dict(sol, r=r, p=p), bw_hist


def subbudget_from_stats(bw_d, w_d, budget):
    """Per-shard C6 sub-budgets from the fleet's (draw, weight) stat vectors.

    ``bw_d``: (D,) each shard's pre-repair bandwidth draw; ``w_d``: (D,)
    each shard's alive-lane weight; ``budget``: () the global C6 budget B.
    The fair split is weight-proportional, but a shard under its fair share
    keeps its whole draw (it is never demoted) and *grants* its headroom to
    the over-budget shards, so only the true global shortfall
    ``max(Σbw − B, 0)`` is demoted — pro-rated over the shards that own
    excess:

        fair_d   = B · w_d / Σw
        excess_d = max(bw_d − fair_d, 0);  head_d = max(fair_d − bw_d, 0)
        target_d = bw_d − excess_d · max(Σexcess − Σhead, 0) / Σexcess

    Since Σexcess − Σhead = Σbw − B, the targets sum to ``min(Σbw, B)``:
    repairing each shard to its target meets C6 *exactly* whenever the
    dense repair would, with zero demotion when the budget has slack.
    With one shard this degenerates to ``min(bw, B)`` — the dense budget.
    """
    bw_d = jnp.asarray(bw_d, jnp.float32)
    w_d = jnp.asarray(w_d, jnp.float32)
    fair = budget * w_d / jnp.maximum(w_d.sum(), 1e-9)
    excess = jnp.maximum(bw_d - fair, 0.0)
    head = jnp.maximum(fair - bw_d, 0.0)
    shortfall = jnp.maximum(excess.sum() - head.sum(), 0.0)
    scale = shortfall / jnp.maximum(excess.sum(), 1e-9)
    return bw_d - excess * scale


def shard_bandwidth_target(local_bw, local_weight, budget, axis_name):
    """This shard's C6 repair target from ONE O(n_devices) scalar exchange.

    Inside ``shard_map``: all-gathers the 2-scalar (draw, weight) stat of
    every shard — the only cross-device traffic the hierarchical repair
    needs — and returns this shard's :func:`subbudget_from_stats` entry.
    Demotion then happens entirely within the shards owning the excess.
    """
    stats = jnp.stack([jnp.asarray(local_bw, jnp.float32),
                       jnp.asarray(local_weight, jnp.float32)])
    stats = jax.lax.all_gather(stats, axis_name)            # (D, 2)
    target = subbudget_from_stats(stats[:, 0], stats[:, 1], budget)
    return target[jax.lax.axis_index(axis_name)]


# ---------------------------------------------------------------------------
# Streaming engine: stateful per-segment routing
# ---------------------------------------------------------------------------
@partial(
    jax.tree_util.register_dataclass,
    data_fields=("prev_route", "prev_tau", "gate"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class RouterState:
    """Carry of the streaming router: per-stream gate recurrence + history."""
    prev_route: jnp.ndarray   # (M,) int32, -1 = no previous segment
    prev_tau: jnp.ndarray     # (M,) float32
    gate: GateBatchState      # fused batch: h (M, m), ring buffer + running Σ/Σ²


def init_router_state(gate_cfg: GateConfig, n_streams: int) -> RouterState:
    return RouterState(
        prev_route=-jnp.ones((n_streams,), jnp.int32),
        prev_tau=jnp.zeros((n_streams,), jnp.float32),
        gate=init_batch_state(gate_cfg, n_streams),
    )


def _two_stage_select(
    prob: RobustProblem,
    taus,                 # (M,) gate scores for THIS segment
    difficulty,           # (M,)
    acc_req,              # (M,)
    prev_route,           # (M,)
    prev_tau,             # (M,)
    rcfg: RouterConfig,
    force: str = "auto",
    tier_ok=None,
):
    """Shared Stage-1 → warm-started CCG → temporal-consistency core.

    Both the streaming step (``route_segment``) and the stateless windowed
    ``route`` run exactly this selection once the gate scores are in hand,
    so routing decisions are identical by construction between the two entry
    points.  Returns the pre-C6 solution with tau / warm diagnostics.

    ``tier_ok``: optional (2,) tier availability.  Outaged tiers are
    infeasible inside the CCG (masked encode) and clamped away after the
    temporal-consistency override — availability beats history.
    """
    lat = prob.lat
    warm_route, warm_r = stage1_configure(
        lat, taus, difficulty, acc_req, prev_route, prev_tau, rcfg,
        tier_ok=tier_ok
    )
    # Stage-1 picks (route, r) at max fps — seed CCG with that configuration
    warm_y = lat.flatten_index(warm_route, warm_r, lat.sys.n_fps - 1)
    sol = solve_ccg_fused(prob, difficulty, acc_req,
                          warm_y=warm_y.astype(jnp.int32), force=force,
                          tier_ok=tier_ok)
    # Stage-1 consistency overrides Stage-2 route flips that the gate forbids
    route = apply_temporal_consistency(
        sol["route"], prev_route, taus, prev_tau, rcfg
    )
    if tier_ok is not None:
        route = clamp_route_available(route, tier_ok)
    sol = dict(sol, route=route)
    sol["tau"] = taus
    sol["warm_route"] = warm_route
    sol["warm_r"] = warm_r
    return sol


def route_segment(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx,                   # (M, d) motion features of THIS segment per stream
    difficulty,           # (M,)
    acc_req,              # (M,)
    rcfg: RouterConfig = RouterConfig(),
    force: str = "auto",
    tier_ok=None,
):
    """Per-stream portion of the streaming step: gate → Stage-1 → CCG →
    temporal consistency.  Everything here is embarrassingly parallel over
    streams (no cross-task reduction), so the sharded ``serve_scan`` runs it
    on each device's local stream shard; the cross-task C6 repair and
    realization happen after.  Returns ``(new_gate, taus, sol)`` with the
    pre-repair solution (tau / warm diagnostics included).
    """
    new_gate, (taus, _gate_means) = gate_step_batch(
        gate_cfg, gate_params, state.gate, dx, force=force
    )
    sol = _two_stage_select(
        prob, taus, difficulty, acc_req, state.prev_route, state.prev_tau,
        rcfg, force=force, tier_ok=tier_ok
    )
    return new_gate, taus, sol


@partial(jax.jit, static_argnames=("gate_cfg", "rcfg", "force"),
         donate_argnames=("state",))
def route_step(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx,                   # (M, d) motion features of THIS segment per stream
    difficulty,           # (M,)
    acc_req,              # (M,)
    rcfg: RouterConfig = RouterConfig(),
    force: str = "auto",
    tier_ok=None,
):
    """One fully jit-compiled streaming step: (state, segment batch) -> (state, sol).

    Advances the fused batched gate by one segment (O(d) incremental
    volatility, Pallas cell on TPU), runs the two-stage robust selection with
    the Stage-1 configuration seeding the CCG scenario set (true warm start),
    applies the temporal-consistency constraint against the carried history,
    and repairs the C6 bandwidth budget.

    ``state`` is donated: the carry buffers are reused for the new state
    instead of being copied every step, so callers must thread the returned
    state (every in-repo caller already does).
    """
    lat = prob.lat
    new_gate, taus, sol = route_segment(
        prob, gate_cfg, gate_params, state, dx, difficulty, acc_req, rcfg,
        force=force, tier_ok=tier_ok
    )
    sol, bw_hist = enforce_bandwidth(lat, sol, difficulty, acc_req,
                                     rounds=rcfg.repair_rounds, force=force)
    sol["bw_history"] = bw_hist
    new_state = RouterState(
        prev_route=sol["route"].astype(jnp.int32),
        prev_tau=taus.astype(jnp.float32),
        gate=new_gate,
    )
    return new_state, sol


@partial(jax.jit, static_argnames=("gate_cfg", "rcfg"), donate_argnames=("state",))
def route_scan(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    state: RouterState,
    dx_seq,               # (S, M, d) segment features, scanned over S
    difficulty,           # (M,) or (S, M)
    acc_req,              # (M,) or (S, M)
    rcfg: RouterConfig = RouterConfig(),
):
    """Run ``route_step`` over S segments under one ``lax.scan``.

    The whole multi-segment round compiles to a single program — no Python
    loop, no per-segment dispatch overhead.  Returns ``(state, sols)`` where
    every entry of ``sols`` is stacked with a leading S axis.
    """
    s = dx_seq.shape[0]
    if difficulty.ndim == 1:
        difficulty = jnp.broadcast_to(difficulty, (s,) + difficulty.shape)
    if acc_req.ndim == 1:
        acc_req = jnp.broadcast_to(acc_req, (s,) + acc_req.shape)

    def body(st, xs):
        dx, z, aq = xs
        st, sol = route_step(prob, gate_cfg, gate_params, st, dx, z, aq, rcfg=rcfg)
        return st, sol

    return jax.lax.scan(body, state, (dx_seq, difficulty, acc_req))


class RouterEngine:
    """Deprecation shim: the streaming R2E-VID engine as a thin wrapper over
    :class:`~repro.serving.session.ServeSession` with the gate-mode
    ``r2evid`` policy.

    Kept with the original signature — ``step`` consumes one (M, d) segment
    feature batch and returns the routing solution, ``step_many`` scans S
    segments in one compiled program — and parity-locked bit-for-bit against
    ``route_step`` / ``route_scan`` (the session's decide path lowers the
    exact same computation).  New code should construct a
    :class:`ServeSession` directly.
    """

    def __init__(self, prob: RobustProblem, gate_cfg: GateConfig, gate_params,
                 n_streams: int, rcfg: RouterConfig = RouterConfig()):
        from repro.serving.policy import R2EVidPolicy
        from repro.serving.session import ServeSession

        self.prob = prob
        self.gate_cfg = gate_cfg
        self.gate_params = gate_params
        self.rcfg = rcfg
        self.session = ServeSession(
            R2EVidPolicy(prob=prob, gate_params=gate_params,
                         gate_cfg=gate_cfg, rcfg=rcfg),
            n_streams=n_streams,
        )

    @property
    def state(self) -> RouterState:
        return self.session.state

    @state.setter
    def state(self, value: RouterState):
        self.session.state = value

    def step(self, dx, difficulty, acc_req):
        from repro.serving.policy import Observation
        return self.session.route(Observation(z=difficulty, aq=acc_req, dx=dx))

    def step_many(self, dx_seq, difficulty, acc_req):
        """Consume S segments in one compiled ``lax.scan``.

        dx_seq: (S, M, d).  Returns the stacked solutions; the last entry is
        the current segment's solution.
        """
        return self.session.route_many(dx_seq, difficulty, acc_req)

    def reset(self, n_streams: int | None = None):
        self.session.reset(n_streams)


# ---------------------------------------------------------------------------
# Full two-stage pipeline (windowed / stateless)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("gate_cfg", "rcfg", "force"))
def route(
    prob: RobustProblem,
    gate_cfg: GateConfig,
    gate_params,
    dx_segments,          # (M, T, d) motion features per stream segment window
    difficulty,           # (M,)
    acc_req,              # (M,)
    prev_route=None,      # (M,) previous segment's route (-1 = none)
    prev_tau=None,
    rcfg: RouterConfig = RouterConfig(),
    force: str = "auto",
    tier_ok=None,
):
    """Windowed stateless routing, jit-compiled end to end.

    Scans the fused batched gate step over the (M, T, d) feature window —
    the same ``gate_step_batch`` cell the streaming engine advances, so the
    windowed API shares its kernel dispatch and incremental volatility
    instead of paying the per-stream ``lax.scan`` composition — then runs
    the same ``_two_stage_select`` + C6 repair as the streaming step.
    """
    m = dx_segments.shape[0]
    if prev_route is None:
        prev_route = -jnp.ones((m,), jnp.int32)
    if prev_tau is None:
        prev_tau = jnp.zeros((m,))

    taus_seq, _gates, _ = gate_window_scan(gate_cfg, gate_params, dx_segments,
                                           force=force)
    taus = taus_seq[:, -1]

    sol = _two_stage_select(
        prob, taus, difficulty, acc_req, prev_route, prev_tau, rcfg,
        force=force, tier_ok=tier_ok
    )
    sol, bw_hist = enforce_bandwidth(prob.lat, sol, difficulty, acc_req,
                                     rounds=rcfg.repair_rounds, force=force)
    sol["bw_history"] = bw_hist
    return sol
