"""R2E-VID core: temporal gating + two-stage robust routing (the paper's
primary contribution)."""
from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables  # noqa: F401
from repro.core.features import feature_dim, motion_features, segment_features  # noqa: F401
from repro.core.gating import GateConfig, gate_loss, gate_scan, gate_scan_batch, gate_specs  # noqa: F401
from repro.core.lattice import DecisionLattice, gflops_table, version_deviations  # noqa: F401
from repro.core.robust import RobustProblem, exact_oracle, solve_ccg, total_cost  # noqa: F401
from repro.core.router import (  # noqa: F401
    RouterConfig,
    RouterEngine,
    RouterState,
    enforce_bandwidth,
    init_router_state,
    route,
    route_step,
    stage1_configure,
)
