"""Motion features Δx_t = φ(I_t, I_{t-1})  (paper §3.2).

φ combines pixel-wise absolute difference and histogram-based motion
magnitude, with 4x spatial downsampling and a temporal moving average of
window 3.  Output: Δx_t ∈ R^d per frame.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DOWNSAMPLE = 4
MA_WINDOW = 3
HIST_BINS = 16
GRID = 4  # spatial pooling grid for the diff map


def feature_dim() -> int:
    return GRID * GRID + HIST_BINS + 3  # grid means + histogram + (mean, std, max)


def _downsample(x, factor: int):
    h, w = x.shape[-2], x.shape[-1]
    h2, w2 = h // factor, w // factor
    x = x[..., : h2 * factor, : w2 * factor]
    x = x.reshape(*x.shape[:-2], h2, factor, w2, factor)
    return x.mean(axis=(-3, -1))


def _soft_histogram(x, bins: int):
    """Differentiable histogram of values in [0, 1]."""
    centers = (jnp.arange(bins) + 0.5) / bins
    width = 1.0 / bins
    w = jax.nn.relu(1.0 - jnp.abs(x[..., None] - centers) / width)  # triangular
    return w.reshape(-1, bins).mean(axis=0)


def _grid_pool(x, grid: int):
    h, w = x.shape[-2], x.shape[-1]
    gh, gw = max(h // grid, 1), max(w // grid, 1)
    x = x[..., : gh * grid, : gw * grid]
    x = x.reshape(grid, gh, grid, gw)
    return x.mean(axis=(1, 3)).reshape(-1)


def frame_diff_features(prev_frame, frame):
    """Single-frame φ before temporal smoothing. frames: (H, W) in [0,1]."""
    diff = jnp.abs(frame - prev_frame)
    diff = _downsample(diff, DOWNSAMPLE)
    grid = _grid_pool(diff, GRID)
    hist = _soft_histogram(jnp.clip(diff, 0.0, 1.0), HIST_BINS)
    stats = jnp.stack([diff.mean(), diff.std(), diff.max()])
    return jnp.concatenate([grid, hist, stats])


def motion_features(frames):
    """frames: (T, H, W) grayscale in [0,1] -> Δx: (T-1, d) with MA-3."""
    feats = jax.vmap(frame_diff_features)(frames[:-1], frames[1:])
    return _moving_average(feats)  # causal temporal moving average, window 3


def _moving_average(feats):
    pad = jnp.concatenate([jnp.repeat(feats[:1], MA_WINDOW - 1, axis=0), feats], axis=0)
    stacked = jnp.stack([pad[i : i + feats.shape[0]] for i in range(MA_WINDOW)], axis=0)
    return stacked.mean(axis=0)


def segment_features(frames, segment_len: int):
    """Split a stream into segments of K frames and mean-pool φ per segment.

    frames: (T, H, W) -> (T // segment_len, d)
    """
    dx = motion_features(frames)  # (T-1, d)
    n = dx.shape[0] // segment_len
    dx = dx[: n * segment_len].reshape(n, segment_len, -1)
    return dx.mean(axis=1)
