"""Unified decision lattice for the two-stage router (paper §3.1).

Every R2E-VID planner — the CCG robust optimizer, the Stage-1 warm start,
the C6 bandwidth repair, and all nominal baselines — searches the same
per-task decision space

    y = (route ∈ {edge, cloud}, r ∈ R, p ∈ P)   first stage, F = 2·N·Z options
    v ∈ V                                        second stage, K versions

Historically each consumer re-derived the flattened index space with its own
``transpose``/``reshape`` math; :class:`DecisionLattice` owns it once:

  * the canonical route-major flat order  y = (route·N + r)·Z + p  and the
    bidirectional ``flatten_index`` / ``unflatten_index`` maps,
  * cached cost tables in both the natural (N, Z, [K,] 2) and flat
    (F[, K]) layouts, plus the per-config bandwidth draw and GFLOPs,
  * vectorized ``accuracy`` / ``accuracy_flat`` / ``feasible_flat`` over
    task batches, and the shared version-deviation vector ũ.

``DecisionLattice.build`` is memoized per :class:`SystemConfig` (the config
is a frozen, hashable dataclass), so planners can call it freely without
rebuilding tables.  The lattice is a registered pytree (``sys`` static,
tables as leaves) and can be closed over or passed through ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import (
    SystemConfig,
    accuracy_table,
    cost_tables,
    fps_norm,
    res_norm,
    version_flops,
)
from repro.kernels.ccg_master.ref import BIG  # shared infeasibility sentinel


def version_deviations(sys: SystemConfig) -> jnp.ndarray:
    """Max relative compute deviation ũ_k per version (K,).

    Deviation grows with model size — bigger models queue worse under load
    (paper §3.3).  Shared by the robust solver, the ablation adapter, and the
    simulator's adversarial-u realization.
    """
    k = jnp.arange(sys.num_versions, dtype=jnp.float32)
    return sys.u_dev * (0.6 + 0.4 * k / (sys.num_versions - 1))


def _gflops_table(sys: SystemConfig) -> np.ndarray:
    """GFLOPs per segment for every (r, p, v, tier): (N, Z, K, 2), float64."""
    fps = np.asarray(sys.fps_options, np.float32)
    gf = np.zeros((sys.n_res, sys.num_versions, 2))
    for i, res in enumerate(sys.resolutions):
        for k in range(sys.num_versions):
            for t in range(2):
                gf[i, k, t] = version_flops(sys, t, k, int(res))
    return gf[:, None, :, :] * fps[None, :, None, None] * sys.segment_sec


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("c1", "b2", "bw", "c1_flat", "b2_flat", "bw_flat", "u_dev",
                 "rn_flat", "pn_flat", "tier_flat"),
    meta_fields=("sys",),
)
@dataclasses.dataclass(frozen=True)
class DecisionLattice:
    sys: SystemConfig
    c1: jnp.ndarray       # (N, Z, 2)    first-stage cost
    b2: jnp.ndarray       # (N, Z, K, 2) second-stage nominal cost
    bw: jnp.ndarray       # (N, Z, 2)    bandwidth draw (Mbps)
    c1_flat: jnp.ndarray  # (F,)         route-major flat first-stage cost
    b2_flat: jnp.ndarray  # (F, K)       route-major flat second-stage cost
    bw_flat: jnp.ndarray  # (F,)         route-major flat bandwidth draw
    u_dev: jnp.ndarray    # (K,)         version deviation vector ũ
    # normalized accuracy-formula coordinates of every flat option — lets the
    # table-free encoders evaluate f(z, y, k) directly in the flat layout
    # (gathers of the same normalized vectors the broadcast table uses, so
    # pointwise evaluation stays bitwise identical to the table)
    rn_flat: jnp.ndarray    # (F,) resolution / 1080
    pn_flat: jnp.ndarray    # (F,) fps / 50
    tier_flat: jnp.ndarray  # (F,) route as float (0 = edge, 1 = cloud)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, sys: SystemConfig) -> "DecisionLattice":
        return _build_cached(sys)

    @property
    def n_flat(self) -> int:
        """F = 2·N·Z first-stage options."""
        return 2 * self.sys.n_res * self.sys.n_fps

    # -- index maps -----------------------------------------------------
    def flatten_index(self, route, r, p):
        """(route, r, p) -> flat first-stage index y (route-major)."""
        return (route * self.sys.n_res + r) * self.sys.n_fps + p

    def unflatten_index(self, y):
        """Flat first-stage index y -> (route, r, p)."""
        nz = self.sys.n_res * self.sys.n_fps
        route = y // nz
        rp = y % nz
        return route, rp // self.sys.n_fps, rp % self.sys.n_fps

    def to_flat(self, table):
        """Reorder a (..., N, Z, K, 2) table into the flat (..., F, K) layout."""
        moved = jnp.moveaxis(table, -1, -4)  # (..., 2, N, Z, K)
        return moved.reshape(*table.shape[:-4], self.n_flat, self.sys.num_versions)

    # -- accuracy / feasibility ----------------------------------------
    def accuracy(self, difficulty):
        """f(r, p, v, y | z): (..., N, Z, K, 2)."""
        return accuracy_table(self.sys, difficulty)

    def accuracy_flat(self, difficulty):
        """Accuracy in the flat layout: (..., F, K)."""
        return self.to_flat(self.accuracy(difficulty))

    def tier_y_ok(self, tier_ok):
        """(..., 2) per-tier availability -> (..., F) flat option mask.

        ``tier_ok[..., t] <= 0`` marks tier t (0 = edge, 1 = cloud) outaged;
        the returned mask is the ``y_ok`` operand every encoder/solver takes
        to make those options infeasible.  Exact gather via ``tier_flat``.
        """
        t = jnp.asarray(tier_ok)
        return jnp.where(self.tier_flat > 0.5, t[..., 1:], t[..., :1])

    def feasible_flat(self, difficulty, acc_req, margin, tier_ok=None):
        """(accuracy_flat, feasibility mask) for a task batch.

        difficulty/acc_req: (M,).  Returns ((M, F, K), (M, F, K) bool) with
        feasibility f >= A^q + margin.  With ``tier_ok`` ((..., 2)
        availability), outaged tiers' options are clamped to -BIG accuracy —
        infeasible AND out of any fallback argmax over the returned surface.
        """
        f = self.accuracy_flat(difficulty)
        if tier_ok is not None:
            f = jnp.where(self.tier_y_ok(tier_ok)[..., None] > 0, f, -BIG)
        return f, f >= (jnp.asarray(acc_req) + margin)[..., None, None]

    # -- solution costing ----------------------------------------------
    def solution_cost(self, sol, u=None):
        """Realized cost c1 + b2·(1+u_v) of a (route, r, p, v) solution."""
        route, r, p, v = sol["route"], sol["r"], sol["p"], sol["v"]
        c1 = self.c1[r, p, route]
        b = self.b2[r, p, v, route]
        if u is not None:
            b = b * (1.0 + jnp.asarray(u)[v])
        return c1 + b

    def solution_bandwidth(self, sol):
        """Per-task bandwidth draw (Mbps) of a (route, r, p) solution."""
        return self.bw[sol["r"], sol["p"], sol["route"]]


@functools.lru_cache(maxsize=32)
def _build_cached(sys: SystemConfig) -> DecisionLattice:
    c1, b2, bw = cost_tables(sys)
    k = sys.num_versions
    f = 2 * sys.n_res * sys.n_fps
    # route-major flat layout: y = (route·N + r)·Z + p
    c1_flat = jnp.moveaxis(c1, -1, 0).reshape(f)
    b2_flat = jnp.moveaxis(b2, -1, 0).reshape(f, k)
    bw_flat = jnp.moveaxis(bw, -1, 0).reshape(f)
    nz = sys.n_res * sys.n_fps
    ys = jnp.arange(f)
    route = ys // nz
    r_idx = (ys % nz) // sys.n_fps
    p_idx = ys % sys.n_fps
    return DecisionLattice(
        sys=sys,
        c1=c1,
        b2=b2,
        bw=bw,
        c1_flat=c1_flat,
        b2_flat=b2_flat,
        bw_flat=bw_flat,
        u_dev=version_deviations(sys),
        rn_flat=res_norm(sys)[r_idx],
        pn_flat=fps_norm(sys)[p_idx],
        tier_flat=route.astype(jnp.float32),
    )


def gflops_table(sys: SystemConfig) -> np.ndarray:
    """Cached (N, Z, K, 2) GFLOPs-per-segment table (float64, host-side)."""
    return _gflops_cached(sys)


@functools.lru_cache(maxsize=32)
def _gflops_cached(sys: SystemConfig) -> np.ndarray:
    return _gflops_table(sys)
