"""Gate meta-training curriculum (paper §3.2).

Offline warm-up on diverse video categories minimizing
L_acc + λ1·L_lat + λ2·L_comp, then online fine-tuning with a proximal
regularizer (μ/2)·||θ − θ_offline||² against catastrophic forgetting.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gating import GateConfig, gate_loss, gate_specs
from repro.models.params import init_params


@dataclasses.dataclass(frozen=True)
class CurriculumConfig:
    warmup_steps: int = 300
    online_steps: int = 100
    lr: float = 3e-3
    lam1: float = 0.05
    lam2: float = 0.01
    mu: float = 0.1


def _sgd_step(params, grads, lr):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


@partial(jax.jit, static_argnames=("gate_cfg", "lam1", "lam2", "mu"))
def _train_step(gate_cfg, params, dxs, labels, lr, lam1, lam2, anchor=None, mu=0.0):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: gate_loss(gate_cfg, p, dxs, labels, lam1, lam2, anchor, mu),
        has_aux=True,
    )(params)
    return _sgd_step(params, grads, lr), loss, metrics


def offline_warmup(gate_cfg: GateConfig, data_iter, ccfg: CurriculumConfig, rng):
    """data_iter yields (dxs (B,T,d), benefit_labels (B,T))."""
    params = init_params(gate_specs(gate_cfg), rng)
    losses = []
    for step, (dxs, labels) in zip(range(ccfg.warmup_steps), data_iter):
        params, loss, _ = _train_step(
            gate_cfg, params, dxs, labels, ccfg.lr, ccfg.lam1, ccfg.lam2
        )
        losses.append(float(loss))
    return params, losses


def online_finetune(gate_cfg: GateConfig, params, data_iter, ccfg: CurriculumConfig):
    """Proximal online adaptation anchored at the offline solution."""
    anchor = jax.tree_util.tree_map(jnp.copy, params)
    losses = []
    for step, (dxs, labels) in zip(range(ccfg.online_steps), data_iter):
        params, loss, _ = _train_step(
            gate_cfg, params, dxs, labels, ccfg.lr * 0.3, ccfg.lam1, ccfg.lam2,
            anchor=anchor, mu=ccfg.mu,
        )
        losses.append(float(loss))
    return params, losses
