"""Two-stage robust optimization (paper §3.1/§3.3, Eq. 2-10, Alg. 2).

Decision lattice per task: first stage y=(route∈{edge,cloud}, r∈R, p∈P)
(50 options), second stage v∈V (K=5 model versions).  The Γ-budget
polyhedral uncertainty set (Eq. 9)

    U = { u : u_k = g_k·ũ_k,  g_k∈[0,1],  Σ_k g_k ≤ Γ }

scales the second-stage cost of model k by (1+u_k) (compute-time deviation
under load/network fluctuation).  By Bertsimas-style strong duality the
worst-case u sits at a pole of U (Eq. 10), so SP is solved *exactly* by pole
enumeration (K=5 ⇒ 2^K = 32 subset poles, filtered to |S| ≤ Γ), and the
column-and-constraint master (Alg. 2) alternates:

    MP1 : y* = argmin_y c1(y) + η(y),  η(y) = max over generated scenarios
          of the recourse value  min_v b2(v; y)·(1+u_j,v)
    SP  : u_{j+1} = argmax_{u∈poles} min_{v feasible} b2(v; y*)·(1+u_v)

until O_up − O_down ≤ θ.  The production solver (:func:`solve_ccg`) runs the
alternation as a *fixed-unroll masked iteration* over the whole task batch:
the scenario set is bounded by the pole count P (an iteration that adds no
new pole has converged), so at most min(max_iters, P+1) masked
master/adversary updates suffice, with a ``done`` flag freezing converged
lanes.  No ``lax.while_loop`` is lowered — the solver is a straight chain of
batched reductions, fully fusable under ``vmap``/``scan``/``shard_map``, and
the hot master reduction dispatches to the Pallas ``ccg_master`` kernel on
TPU.  :func:`solve_ccg_while` keeps the original per-task ``while_loop``
solver as the decision-identity oracle; ``exact_oracle`` brute-forces
min_y max_u min_v for tests.

All flattened-index bookkeeping lives in :class:`DecisionLattice`
(``repro.core.lattice``) — this module never reshapes the lattice itself.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig
from repro.core.lattice import DecisionLattice
from repro.kernels.ccg_encode.ops import ccg_encode
from repro.kernels.ccg_master.ops import ccg_master
from repro.kernels.ccg_master.ref import BIG  # shared infeasibility sentinel
from repro.kernels.ccg_solve.ops import ccg_solve


def _poles(num_versions: int, gamma: int):
    """All subset poles of U with |S| <= gamma: (P, K) in {0,1}."""
    k = num_versions
    masks = []
    for bits in range(2 ** k):
        s = [(bits >> i) & 1 for i in range(k)]
        if sum(s) <= gamma:
            masks.append(s)
    return jnp.asarray(masks, jnp.float32)  # (P, K)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("lat", "poles", "rec_table", "b2_scaled"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class RobustProblem:
    lat: DecisionLattice
    poles: jnp.ndarray     # (P, K) pole indicators
    # (P, F, 2^K) recourse lookup: min_v b2·(1+u_v) over the feasible-version
    # subset encoded as a bitmask.  Task-independent (depends only on the
    # lattice costs, poles, and ũ), built once; the per-task CCG sweep then
    # reduces to encoding its (F, K) feasibility mask and gathering.
    rec_table: jnp.ndarray
    # (P, F, K) pole-scaled second-stage costs b2·(1+u) — the unexpanded form
    # of the same lookup; the Pallas encode kernel keeps this slab
    # VMEM-resident and min-folds it instead of gathering rec_table
    b2_scaled: jnp.ndarray

    @classmethod
    def build(cls, sys: SystemConfig):
        lat = DecisionLattice.build(sys)
        poles = _poles(sys.num_versions, sys.gamma)
        u_all = poles * lat.u_dev                             # (P, K)
        b2_scaled = lat.b2_flat[None] * (1.0 + u_all[:, None, :])  # (P, F, K)
        k = sys.num_versions
        masks = ((jnp.arange(2 ** k)[:, None] >> jnp.arange(k)[None]) & 1).astype(bool)
        rec_table = jnp.where(
            masks[None, None], b2_scaled[:, :, None, :], BIG
        ).min(axis=-1)                                        # (P, F, 2^K)
        return cls(lat=lat, poles=poles, rec_table=rec_table,
                   b2_scaled=b2_scaled)

    @property
    def sys(self) -> SystemConfig:
        return self.lat.sys

    @property
    def u_dev(self):
        """(K,) max deviations ũ_k — single source of truth is the lattice."""
        return self.lat.u_dev

    # back-compat views of the cost tables (natural layout)
    @property
    def c1(self):
        return self.lat.c1

    @property
    def b2(self):
        return self.lat.b2


def _encode_tasks(prob: RobustProblem, difficulty, acc_req, tier_ok=None):
    """Table-based per-task CCG inputs — the encode ORACLE.

    Builds the full (M, F, K) accuracy tensor via the broadcast table, then
    derives the feasibility masks and gathers the recourse slab.  Kept for
    the while_loop oracle and the ``ccg_encode`` parity tests; the serving
    hot path uses :func:`_encode_tasks_fused` (bit-identical, table-free).
    ``tier_ok``: optional (..., 2) per-tier availability — outaged tiers'
    options drop to -BIG accuracy (infeasible, out of any fallback argmax).
    Returns ``(f_flat, feas_f, fs_ok, rec_all)`` with shapes
    ((M, F, K), (M, F, K), (M, F), (M, P, F)).
    """
    lat = prob.lat
    sys = lat.sys
    # C1 protected with the robust accuracy margin (h in the Benders cuts)
    f_flat, feas_f = lat.feasible_flat(difficulty, acc_req,
                                       sys.acc_margin_robust, tier_ok=tier_ok)
    pow2 = 2 ** jnp.arange(sys.num_versions)
    code = (feas_f * pow2[None, None]).sum(axis=-1)   # (M, F) subset codes
    rec_all = jnp.take_along_axis(
        prob.rec_table[None], code[:, None, :, None], axis=-1
    )[..., 0]                                         # (M, P, F)
    return f_flat, feas_f, feas_f.any(axis=-1), rec_all


def _encode_tasks_fused(prob: RobustProblem, difficulty, acc_req,
                        force: str = "auto", tier_ok=None):
    """Table-free per-task CCG inputs via the fused ``ccg_encode`` kernel.

    No (M, N, Z, K, 2) or (M, F, K) accuracy tensor is built anywhere:
    the kernel/ref evaluate the accuracy formula per version directly in the
    flat layout, emit the (M, F) feasible-version bitmask ``code``, the
    (M, P, F) recourse slab, and the flat accuracy argmax ``best`` consumed
    by the all-infeasible fallback.  Bit-identical to :func:`_encode_tasks`
    (parity-tested in tests/test_kernels.py).  ``tier_ok``: optional (2,)
    per-tier availability, lowered to the kernel's (F,) ``y_ok`` mask.
    """
    lat = prob.lat
    y_ok = None if tier_ok is None else lat.tier_y_ok(tier_ok)
    return ccg_encode(
        jnp.asarray(difficulty, jnp.float32), jnp.asarray(acc_req, jnp.float32),
        lat.rn_flat, lat.pn_flat, lat.tier_flat,
        prob.b2_scaled, prob.rec_table,
        margin=lat.sys.acc_margin_robust, num_versions=lat.sys.num_versions,
        force=force, y_ok=y_ok,
    )


def _finish_solution(prob: RobustProblem, code, best, rec_all, y_f):
    """Shared epilogue: final recourse v*, infeasibility fallback, unflatten.

    y_f: (M,) converged first-stage indices; code: the (M, F) feasibility
    bitmask; best: (M,) flat accuracy argmax.  Picks v* at the worst pole of
    y_f, then applies the graceful margin relaxation (tasks infeasible *with*
    the robust margin fall back to the max-accuracy configuration).  All
    per-task work is O(M) gathers and bit tests — no accuracy table.
    """
    lat = prob.lat
    sys = lat.sys
    b2 = lat.b2_flat
    sp_vals = jnp.take_along_axis(rec_all, y_f[:, None, None], axis=2)[..., 0]
    worst = sp_vals.argmax(axis=1)                    # (M,)
    u = prob.poles[worst] * prob.u_dev[None]          # (M, K)
    code_y = jnp.take_along_axis(code, y_f[:, None], axis=1)[:, 0]
    feas_y = ((code_y[:, None] >> jnp.arange(sys.num_versions)[None]) & 1) > 0
    vals = jnp.where(feas_y, b2[y_f] * (1.0 + u), BIG)
    v_star = vals.argmin(axis=1)
    none_ok = ~(code > 0).any(axis=1)
    y_f = jnp.where(none_ok, best // sys.num_versions, y_f)
    v_star = jnp.where(none_ok, best % sys.num_versions, v_star)
    route, r_idx, p_idx = lat.unflatten_index(y_f)
    return route, r_idx, p_idx, v_star, none_ok


@partial(jax.jit, static_argnames=("max_iters", "force"))
def solve_ccg(prob: RobustProblem, difficulty, acc_req, max_iters: int = 8,
              theta: float = 1e-4, warm_y=None, force: str = "auto",
              tier_ok=None):
    """Alg. 2 for a batch of tasks — fixed-unroll masked iteration.

    difficulty: (M,) content difficulty z; acc_req: (M,) A^q_i.
    Returns dict with y (route), r, p, v indices + objective bounds.

    Instead of a per-task ``lax.while_loop`` (whose batched lowering carries
    ~1 ms of fixed overhead per call on CPU and blocks fusion), the CCG
    alternation is unrolled min(max_iters, P+1) times over the *whole* batch:
    each SP step either adds a new pole to a task's scenario set or proves
    convergence, so P+1 masked steps are exact, and a ``done`` flag freezes
    converged lanes (their state stops updating, exactly as if the loop had
    exited).  Decisions, bounds, and iteration counts are bit-identical to
    :func:`solve_ccg_while`.

    The master reduction (η-max over generated scenarios, feasibility mask,
    argmin over F) dispatches to the Pallas ``ccg_master`` kernel on TPU,
    which keeps the whole (P, F) recourse slab VMEM-resident per tile.  Off
    TPU the same master is computed incrementally: η is a running (M, F) max
    folded in as each pole is generated (max is exact in floats, so the
    running form is bit-identical to the masked slab reduction) — O(M·F) per
    iteration instead of O(M·P·F).  The per-task inputs come from the fused
    table-free ``ccg_encode`` kernel (accuracy formula → feasibility bitmask
    → recourse slab in one pass; no (M, F, K) tensor anywhere).  ``force``
    pins both the encode and master implementations for tests: "pallas"
    (interpret off-TPU) / "ref" exercise the kernel ops, "auto" picks the
    backend default.

    ``warm_y``: optional (M,) flat first-stage warm starts (the Stage-1
    route).  When given, each task's scenario set is seeded with the exact
    worst-case pole of its warm start and O_up starts at that configuration's
    robust cost — a valid upper bound whenever the warm start is feasible —
    so typical tasks converge in fewer CCG iterations.

    ``tier_ok``: optional (2,) per-tier availability; outaged tiers' options
    become infeasible and drop out of the all-infeasible fallback.
    """
    lat = prob.lat
    c1 = lat.c1_flat                                  # (F,)
    code, rec_all, best = _encode_tasks_fused(prob, difficulty, acc_req,
                                              force=force, tier_ok=tier_ok)
    fs_ok = code > 0                                  # (M, F)
    m = code.shape[0]
    n_poles = prob.poles.shape[0]
    if warm_y is None:
        warm_y = -jnp.ones(m, jnp.int32)

    # warm start: seed the scenario set with the warm y's worst pole and
    # start O_up at its robust cost (only when the warm start is usable)
    wy = jnp.maximum(warm_y, 0)
    use_warm = (warm_y >= 0) & jnp.take_along_axis(fs_ok, wy[:, None], axis=1)[:, 0]
    rec_wy = jnp.take_along_axis(rec_all, wy[:, None, None], axis=2)[..., 0]
    warm_pole = rec_wy.argmax(axis=1)                 # (M,)
    warm_up = c1[wy] + jnp.take_along_axis(rec_wy, warm_pole[:, None], axis=1)[:, 0]
    o_up = jnp.where(use_warm, warm_up, BIG)
    o_down = jnp.full((m,), -BIG)
    y_best = wy
    done = jnp.zeros((m,), bool)
    iters = jnp.zeros((m,), jnp.int32)

    # master-step state: the Pallas slab kernel consumes the (M, P) scenario
    # mask against the full recourse slab; the jnp path folds each generated
    # pole into a running (M, F) η-max (bit-identical — max is exact)
    slab_master = force != "auto" or jax.default_backend() == "tpu"
    if slab_master:
        pole_iota = jnp.arange(n_poles)[None, :]      # (1, P)
        scen_mask = jnp.where(
            use_warm[:, None] & (pole_iota == warm_pole[:, None]), 1.0, 0.0)
    else:
        rec_warm = jnp.take_along_axis(
            rec_all, warm_pole[:, None, None], axis=1)[:, 0]       # (M, F)
        eta_run = jnp.where(use_warm[:, None], rec_warm, -BIG)
        has_scen = use_warm

    for _ in range(min(max_iters, n_poles + 1)):
        live = ~done
        # MP1: eta(y) = max over generated scenarios of the recourse value,
        # obj = c1 + eta masked to feasible options, argmin over F
        if slab_master:
            y_star, od_new = ccg_master(rec_all, scen_mask, fs_ok, c1, force=force)
        else:
            eta = jnp.where(has_scen[:, None], eta_run, 0.0)
            obj = jnp.where(fs_ok, c1[None] + eta, BIG)
            y_star = obj.argmin(axis=1).astype(jnp.int32)
            od_new = jnp.take_along_axis(obj, y_star[:, None], axis=1)[:, 0]
        # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
        sp_vals = jnp.take_along_axis(rec_all, y_star[:, None, None], axis=2)[..., 0]
        worst_pole = sp_vals.argmax(axis=1)           # (M,)
        q = jnp.take_along_axis(sp_vals, worst_pole[:, None], axis=1)[:, 0]
        cand = c1[y_star] + q
        # the returned decision is the INCUMBENT achieving O_up, not the
        # last master argmin — the master's obj only lower-bounds the
        # robust cost, so a θ-tied y_star may be worse than the incumbent
        up_new = jnp.minimum(o_up, cand)
        # freeze converged lanes: done lanes keep their pre-convergence state
        y_best = jnp.where(live & (cand < o_up), y_star, y_best)
        o_down = jnp.where(live, od_new, o_down)
        o_up = jnp.where(live, up_new, o_up)
        if slab_master:
            # add the scenario column as a one-hot max (XLA scatter is slow)
            mask_new = jnp.maximum(
                scen_mask, (pole_iota == worst_pole[:, None]).astype(scen_mask.dtype))
            scen_mask = jnp.where(live[:, None], mask_new, scen_mask)
        else:
            rec_new = jnp.take_along_axis(
                rec_all, worst_pole[:, None, None], axis=1)[:, 0]   # (M, F)
            eta_run = jnp.where(
                live[:, None], jnp.maximum(eta_run, rec_new), eta_run)
            has_scen = has_scen | live
        iters = iters + live.astype(jnp.int32)
        done = jnp.where(live, (up_new - od_new) <= theta, done)

    route, r_idx, p_idx, v_star, none_ok = _finish_solution(
        prob, code, best, rec_all, y_best)
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


@partial(jax.jit, static_argnames=("max_iters", "theta", "force"))
def solve_ccg_fused(prob: RobustProblem, difficulty, acc_req,
                    max_iters: int = 8, theta: float = 1e-4, warm_y=None,
                    force: str = "auto", tier_ok=None):
    """Alg. 2 as ONE fused solve — the serving hot path since PR 6.

    Same contract as :func:`solve_ccg` (decisions, bounds, and iteration
    counts are bit-identical — parity-locked in tests), but the entire
    alternation (encode → master argmin → SP pole selection → η update,
    min(max_iters, P+1) steps) dispatches to the ``ccg_solve`` kernel triple
    instead of one encode + one master call per unrolled step.  No (M, P, F)
    recourse slab exists anywhere: η is a running (M, F) max and recourse
    values are K-fold masked mins over the (F, K) cost table (exact — see
    kernels/ccg_solve).  The jnp ref is the CPU hot path with a batch-level
    early-exit while_loop + live-lane compaction; the Pallas kernel keeps
    the per-lane solver state VMEM-resident across all steps on TPU.

    ``solve_ccg`` and ``solve_ccg_while`` are retained as the bit-exact
    oracles (and for the slab-master Pallas path's parity tests).

    ``tier_ok``: optional (2,) per-tier availability; outaged tiers' options
    become infeasible and drop out of the all-infeasible fallback.
    """
    lat = prob.lat
    if warm_y is None:
        warm_y = -jnp.ones(jnp.asarray(difficulty).shape[0], jnp.int32)
    y_ok = None if tier_ok is None else lat.tier_y_ok(tier_ok)
    y_f, v_star, o_up, o_down, iters, none_ok = ccg_solve(
        jnp.asarray(difficulty, jnp.float32), jnp.asarray(acc_req, jnp.float32),
        lat.rn_flat, lat.pn_flat, lat.tier_flat, lat.b2_flat,
        prob.poles * lat.u_dev, lat.c1_flat, warm_y.astype(jnp.int32),
        margin=lat.sys.acc_margin_robust, num_versions=lat.sys.num_versions,
        max_iters=max_iters, theta=theta, force=force, y_ok=y_ok)
    route, r_idx, p_idx = lat.unflatten_index(y_f)
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


@partial(jax.jit, static_argnames=("max_iters",))
def solve_ccg_while(prob: RobustProblem, difficulty, acc_req, max_iters: int = 8,
                    theta: float = 1e-4, warm_y=None, tier_ok=None):
    """Original per-task ``lax.while_loop`` CCG — the unrolled solver's
    decision-identity oracle (kept out of the serving hot path)."""
    lat = prob.lat
    sys = lat.sys
    c1 = lat.c1_flat                                  # (F,)
    b2 = lat.b2_flat                                  # (F, K)
    f_flat, feas_f, _, rec_all_m = _encode_tasks(prob, difficulty, acc_req,
                                                 tier_ok=tier_ok)
    if warm_y is None:
        warm_y = -jnp.ones(feas_f.shape[0], jnp.int32)

    def per_task(feas_i, rec_all, warm_i):
        # any first-stage option with no feasible v is excluded from MP1
        fs_ok = feas_i.any(axis=-1)                      # (F,)

        # warm start: seed the scenario set with the warm y's worst pole and
        # start O_up at its robust cost (only when the warm start is usable)
        use_warm = (warm_i >= 0) & fs_ok[jnp.maximum(warm_i, 0)]
        wy = jnp.maximum(warm_i, 0)
        warm_pole = rec_all[:, wy].argmax()
        warm_up = c1[wy] + rec_all[warm_pole, wy]
        init_mask = jnp.zeros((prob.poles.shape[0],)).at[warm_pole].set(
            jnp.where(use_warm, 1.0, 0.0))
        init_up = jnp.where(use_warm, warm_up, BIG)

        def body(carry):
            it, scen_mask, o_up, _, y_best, done = carry
            # MP1: eta(y) = max over generated scenarios of the recourse value
            active = jnp.where(scen_mask[:, None] > 0, rec_all, -BIG)
            eta = jnp.where(scen_mask.sum() > 0, active.max(axis=0), 0.0)  # (F,)
            obj = jnp.where(fs_ok, c1 + eta, BIG)
            y_star = obj.argmin()
            o_down = obj[y_star]
            # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
            sp_vals = rec_all[:, y_star]                 # (P,)
            worst_pole = sp_vals.argmax()
            q = sp_vals[worst_pole]
            # the returned decision is the INCUMBENT achieving O_up, not the
            # last master argmin — the master's obj only lower-bounds the
            # robust cost, so a θ-tied y_star may be worse than the incumbent
            # (matters when the warm seed makes convergence fire early)
            y_best = jnp.where(c1[y_star] + q < o_up, y_star, y_best)
            o_up = jnp.minimum(o_up, c1[y_star] + q)
            done = (o_up - o_down) <= theta
            scen_mask = scen_mask.at[worst_pole].set(1.0)  # add scenario column
            return it + 1, scen_mask, o_up, o_down, y_best, done

        def cond(carry):
            it, _, _, _, _, done = carry
            return (it < max_iters) & ~done

        init = (0, init_mask, init_up, jnp.asarray(-BIG),
                wy, jnp.asarray(False))
        it, scen_mask, o_up, o_down, y_star, done = jax.lax.while_loop(cond, body, init)

        # final recourse: worst pole for chosen y, then v*
        sp_vals = rec_all[:, y_star]
        worst = sp_vals.argmax()
        u = prob.poles[worst] * prob.u_dev
        vals = jnp.where(feas_i[y_star], b2[y_star] * (1.0 + u), BIG)
        v_star = vals.argmin()
        return y_star, v_star, o_up, o_down, it

    y_f, v_star, o_up, o_down, iters = jax.vmap(per_task)(feas_f, rec_all_m, warm_y)
    # graceful margin relaxation: tasks infeasible *with* the robust margin
    # fall back to the max-accuracy configuration (which also covers margin-
    # free feasibility when any config clears A^q exactly)
    none_ok = ~feas_f.any(axis=(1, 2))
    best_acc = f_flat.reshape(f_flat.shape[0], -1).argmax(axis=1)
    ba_f = best_acc // sys.num_versions
    ba_v = best_acc % sys.num_versions
    y_f = jnp.where(none_ok, ba_f, y_f)
    v_star = jnp.where(none_ok, ba_v, v_star)
    route, r_idx, p_idx = lat.unflatten_index(y_f)
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


def solve_ccg_sharded(prob: RobustProblem, difficulty, acc_req, mesh,
                      axis: str = "data", max_iters: int = 8,
                      theta: float = 1e-4, warm_y=None):
    """``solve_ccg`` with the task batch M split across devices.

    The CCG sweep is embarrassingly parallel over tasks (the hoisted
    (P, F, K) recourse table is replicated; only the per-task feasibility
    masks and loop state are local), so a ``shard_map`` over the mesh's data
    axis scales the sweep linearly with device count.  The batch is padded to
    a multiple of the axis size with trivially-feasible dummies and sliced
    back, so any M works.  Decisions are identical to the single-device path.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import pad_leading, shard_map

    m = difficulty.shape[0]
    n_dev = mesh.shape[axis]
    pad = (-m) % n_dev
    difficulty = pad_leading(difficulty, pad)
    acc_req = pad_leading(acc_req, pad)
    if warm_y is None:
        warm_y = -jnp.ones((m,), jnp.int32)
    warm_y = pad_leading(warm_y, pad, value=-1)

    def shard_fn(pb, z, aq, wy):
        return solve_ccg(pb, z, aq, max_iters=max_iters, theta=theta, warm_y=wy)

    # check_vma=False: the replicated problem tables have no tracked
    # replication rule, but every operand is either axis-sharded or an
    # explicitly replicated input
    sol = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis)),
        out_specs=P(axis), check_vma=False,
    )(prob, difficulty, acc_req, warm_y)
    return {k: v[:m] for k, v in sol.items()}


def exact_oracle(prob: RobustProblem, difficulty, acc_req, tier_ok=None):
    """Brute force min_y max_{u∈poles} min_v — test oracle."""
    lat = prob.lat
    c1 = lat.c1_flat
    b2 = lat.b2_flat
    _, feas_f = lat.feasible_flat(difficulty, acc_req,
                                  lat.sys.acc_margin_robust, tier_ok=tier_ok)

    def per_task(feas_i):
        u = prob.poles[:, None, :] * prob.u_dev        # (P, 1, K)
        vals = jnp.where(feas_i[None], b2[None] * (1.0 + u), BIG)  # (P, F, K)
        rec = vals.min(axis=-1)                         # (P, F)
        worst = rec.max(axis=0)                         # (F,)
        fs_ok = feas_i.any(axis=-1)
        obj = jnp.where(fs_ok, c1 + worst, BIG)
        y = obj.argmin()
        return y, obj[y]

    y, obj = jax.vmap(per_task)(feas_f)
    return y, obj


def total_cost(prob: RobustProblem, sol, difficulty, acc_req, u=None):
    """Realized cost of a solution under deviation u ((K,) or None=nominal)."""
    return prob.lat.solution_cost(sol, u=u)
