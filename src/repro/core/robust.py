"""Two-stage robust optimization (paper §3.1/§3.3, Eq. 2-10, Alg. 2).

Decision lattice per task: first stage y=(route∈{edge,cloud}, r∈R, p∈P)
(50 options), second stage v∈V (K=5 model versions).  The Γ-budget
polyhedral uncertainty set (Eq. 9)

    U = { u : u_k = g_k·ũ_k,  g_k∈[0,1],  Σ_k g_k ≤ Γ }

scales the second-stage cost of model k by (1+u_k) (compute-time deviation
under load/network fluctuation).  By Bertsimas-style strong duality the
worst-case u sits at a pole of U (Eq. 10), so SP is solved *exactly* by pole
enumeration (K=5 ⇒ 2^K = 32 subset poles, filtered to |S| ≤ Γ), and the
column-and-constraint master (Alg. 2) alternates:

    MP1 : y* = argmin_y c1(y) + η(y),  η(y) = max over generated scenarios
          of the recourse value  min_v b2(v; y)·(1+u_j,v)
    SP  : u_{j+1} = argmax_{u∈poles} min_{v feasible} b2(v; y*)·(1+u_v)

until O_up − O_down ≤ θ.  Everything is vectorized over tasks with vmap;
``exact_oracle`` brute-forces min_y max_u min_v for tests.

All flattened-index bookkeeping lives in :class:`DecisionLattice`
(``repro.core.lattice``) — this module never reshapes the lattice itself.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig
from repro.core.lattice import DecisionLattice

BIG = 1e9


def _poles(num_versions: int, gamma: int):
    """All subset poles of U with |S| <= gamma: (P, K) in {0,1}."""
    k = num_versions
    masks = []
    for bits in range(2 ** k):
        s = [(bits >> i) & 1 for i in range(k)]
        if sum(s) <= gamma:
            masks.append(s)
    return jnp.asarray(masks, jnp.float32)  # (P, K)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("lat", "poles"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class RobustProblem:
    lat: DecisionLattice
    poles: jnp.ndarray     # (P, K) pole indicators

    @classmethod
    def build(cls, sys: SystemConfig):
        lat = DecisionLattice.build(sys)
        poles = _poles(sys.num_versions, sys.gamma)
        return cls(lat=lat, poles=poles)

    @property
    def sys(self) -> SystemConfig:
        return self.lat.sys

    @property
    def u_dev(self):
        """(K,) max deviations ũ_k — single source of truth is the lattice."""
        return self.lat.u_dev

    # back-compat views of the cost tables (natural layout)
    @property
    def c1(self):
        return self.lat.c1

    @property
    def b2(self):
        return self.lat.b2


def recourse_value(prob: RobustProblem, feas, b2_yrp, pole):
    """min_v (1+u_v)·b2_v over feasible v for one pole. b2_yrp: (K,)."""
    u = pole * prob.u_dev
    vals = jnp.where(feas, b2_yrp * (1.0 + u), BIG)
    return vals.min(), vals.argmin()


@partial(jax.jit, static_argnames=("max_iters",))
def solve_ccg(prob: RobustProblem, difficulty, acc_req, max_iters: int = 8, theta: float = 1e-4):
    """Alg. 2 for a batch of tasks.

    difficulty: (M,) content difficulty z; acc_req: (M,) A^q_i.
    Returns dict with y (route), r, p, v indices + objective bounds.
    """
    lat = prob.lat
    sys = lat.sys
    # C1 protected with the robust accuracy margin (h in the Benders cuts)
    f_flat, feas_f = lat.feasible_flat(difficulty, acc_req, sys.acc_margin_robust)
    c1 = lat.c1_flat                                  # (F,)
    b2 = lat.b2_flat                                  # (F, K)

    def per_task(feas_i):
        # any first-stage option with no feasible v is excluded from MP1
        fs_ok = feas_i.any(axis=-1)                      # (F,)

        def pole_recourse(u_mask):
            u = u_mask * prob.u_dev                      # (K,)
            vals = jnp.where(feas_i, b2 * (1.0 + u), BIG)  # (F, K)
            return vals.min(axis=-1)                     # (F,)

        # worst-case over ALL poles for every F (used for oracle + SP)
        rec_all = jax.vmap(pole_recourse)(prob.poles)    # (P, F)

        def body(carry):
            it, scen_mask, o_up, _, _, done = carry
            # MP1: eta(y) = max over generated scenarios of the recourse value
            active = jnp.where(scen_mask[:, None] > 0, rec_all, -BIG)
            eta = jnp.where(scen_mask.sum() > 0, active.max(axis=0), 0.0)  # (F,)
            obj = jnp.where(fs_ok, c1 + eta, BIG)
            y_star = obj.argmin()
            o_down = obj[y_star]
            # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
            sp_vals = rec_all[:, y_star]                 # (P,)
            worst_pole = sp_vals.argmax()
            q = sp_vals[worst_pole]
            o_up = jnp.minimum(o_up, c1[y_star] + q)
            done = (o_up - o_down) <= theta
            scen_mask = scen_mask.at[worst_pole].set(1.0)  # add scenario column
            return it + 1, scen_mask, o_up, o_down, y_star, done

        def cond(carry):
            it, _, _, _, _, done = carry
            return (it < max_iters) & ~done

        p = prob.poles.shape[0]
        init = (0, jnp.zeros((p,)), jnp.asarray(BIG), jnp.asarray(-BIG),
                jnp.asarray(0, dtype=jnp.int32), jnp.asarray(False))
        it, scen_mask, o_up, o_down, y_star, done = jax.lax.while_loop(cond, body, init)

        # final recourse: worst pole for chosen y, then v*
        sp_vals = rec_all[:, y_star]
        worst = sp_vals.argmax()
        u = prob.poles[worst] * prob.u_dev
        vals = jnp.where(feas_i[y_star], b2[y_star] * (1.0 + u), BIG)
        v_star = vals.argmin()
        return y_star, v_star, o_up, o_down, it

    y_f, v_star, o_up, o_down, iters = jax.vmap(per_task)(feas_f)
    # graceful margin relaxation: tasks infeasible *with* the robust margin
    # fall back to the max-accuracy configuration (which also covers margin-
    # free feasibility when any config clears A^q exactly)
    none_ok = ~feas_f.any(axis=(1, 2))
    best_acc = f_flat.reshape(f_flat.shape[0], -1).argmax(axis=1)
    ba_f = best_acc // sys.num_versions
    ba_v = best_acc % sys.num_versions
    y_f = jnp.where(none_ok, ba_f, y_f)
    v_star = jnp.where(none_ok, ba_v, v_star)
    route, r_idx, p_idx = lat.unflatten_index(y_f)
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


def exact_oracle(prob: RobustProblem, difficulty, acc_req):
    """Brute force min_y max_{u∈poles} min_v — test oracle."""
    lat = prob.lat
    c1 = lat.c1_flat
    b2 = lat.b2_flat
    _, feas_f = lat.feasible_flat(difficulty, acc_req, lat.sys.acc_margin_robust)

    def per_task(feas_i):
        u = prob.poles[:, None, :] * prob.u_dev        # (P, 1, K)
        vals = jnp.where(feas_i[None], b2[None] * (1.0 + u), BIG)  # (P, F, K)
        rec = vals.min(axis=-1)                         # (P, F)
        worst = rec.max(axis=0)                         # (F,)
        fs_ok = feas_i.any(axis=-1)
        obj = jnp.where(fs_ok, c1 + worst, BIG)
        y = obj.argmin()
        return y, obj[y]

    y, obj = jax.vmap(per_task)(feas_f)
    return y, obj


def total_cost(prob: RobustProblem, sol, difficulty, acc_req, u=None):
    """Realized cost of a solution under deviation u ((K,) or None=nominal)."""
    return prob.lat.solution_cost(sol, u=u)
