"""Two-stage robust optimization (paper §3.1/§3.3, Eq. 2-10, Alg. 2).

Decision lattice per task: first stage y=(route∈{edge,cloud}, r∈R, p∈P)
(50 options), second stage v∈V (K=5 model versions).  The Γ-budget
polyhedral uncertainty set (Eq. 9)

    U = { u : u_k = g_k·ũ_k,  g_k∈[0,1],  Σ_k g_k ≤ Γ }

scales the second-stage cost of model k by (1+u_k) (compute-time deviation
under load/network fluctuation).  By Bertsimas-style strong duality the
worst-case u sits at a pole of U (Eq. 10), so SP is solved *exactly* by pole
enumeration (K=5 ⇒ 2^K = 32 subset poles, filtered to |S| ≤ Γ), and the
column-and-constraint master (Alg. 2) alternates:

    MP1 : y* = argmin_y c1(y) + η(y),  η(y) = max over generated scenarios
          of the recourse value  min_v b2(v; y)·(1+u_j,v)
    SP  : u_{j+1} = argmax_{u∈poles} min_{v feasible} b2(v; y*)·(1+u_v)

until O_up − O_down ≤ θ.  Everything is vectorized over tasks with vmap;
``exact_oracle`` brute-forces min_y max_u min_v for tests.

All flattened-index bookkeeping lives in :class:`DecisionLattice`
(``repro.core.lattice``) — this module never reshapes the lattice itself.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig
from repro.core.lattice import DecisionLattice

BIG = 1e9


def _poles(num_versions: int, gamma: int):
    """All subset poles of U with |S| <= gamma: (P, K) in {0,1}."""
    k = num_versions
    masks = []
    for bits in range(2 ** k):
        s = [(bits >> i) & 1 for i in range(k)]
        if sum(s) <= gamma:
            masks.append(s)
    return jnp.asarray(masks, jnp.float32)  # (P, K)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("lat", "poles", "rec_table"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class RobustProblem:
    lat: DecisionLattice
    poles: jnp.ndarray     # (P, K) pole indicators
    # (P, F, 2^K) recourse lookup: min_v b2·(1+u_v) over the feasible-version
    # subset encoded as a bitmask.  Task-independent (depends only on the
    # lattice costs, poles, and ũ), built once; the per-task CCG sweep then
    # reduces to encoding its (F, K) feasibility mask and gathering.
    rec_table: jnp.ndarray

    @classmethod
    def build(cls, sys: SystemConfig):
        lat = DecisionLattice.build(sys)
        poles = _poles(sys.num_versions, sys.gamma)
        u_all = poles * lat.u_dev                             # (P, K)
        b2_scaled = lat.b2_flat[None] * (1.0 + u_all[:, None, :])  # (P, F, K)
        k = sys.num_versions
        masks = ((jnp.arange(2 ** k)[:, None] >> jnp.arange(k)[None]) & 1).astype(bool)
        rec_table = jnp.where(
            masks[None, None], b2_scaled[:, :, None, :], BIG
        ).min(axis=-1)                                        # (P, F, 2^K)
        return cls(lat=lat, poles=poles, rec_table=rec_table)

    @property
    def sys(self) -> SystemConfig:
        return self.lat.sys

    @property
    def u_dev(self):
        """(K,) max deviations ũ_k — single source of truth is the lattice."""
        return self.lat.u_dev

    # back-compat views of the cost tables (natural layout)
    @property
    def c1(self):
        return self.lat.c1

    @property
    def b2(self):
        return self.lat.b2


@partial(jax.jit, static_argnames=("max_iters",))
def solve_ccg(prob: RobustProblem, difficulty, acc_req, max_iters: int = 8,
              theta: float = 1e-4, warm_y=None):
    """Alg. 2 for a batch of tasks.

    difficulty: (M,) content difficulty z; acc_req: (M,) A^q_i.
    Returns dict with y (route), r, p, v indices + objective bounds.

    The scaled recourse table b2·(1+u) over all poles is task-independent, so
    it is hoisted out of the per-task vmap entirely: ``RobustProblem`` caches
    its mins over every feasible-version subset, and each task just encodes
    its (F, K) feasibility mask as a bitmask and gathers.

    ``warm_y``: optional (M,) flat first-stage warm starts (the Stage-1
    route).  When given, each task's scenario set is seeded with the exact
    worst-case pole of its warm start and O_up starts at that configuration's
    robust cost — a valid upper bound whenever the warm start is feasible —
    so typical tasks converge in fewer CCG iterations.
    """
    lat = prob.lat
    sys = lat.sys
    # C1 protected with the robust accuracy margin (h in the Benders cuts)
    f_flat, feas_f = lat.feasible_flat(difficulty, acc_req, sys.acc_margin_robust)
    c1 = lat.c1_flat                                  # (F,)
    b2 = lat.b2_flat                                  # (F, K)
    # hoisted recourse: the scaled b2·(1+u) mins live in the precomputed
    # task-independent (P, F, 2^K) table — each task only encodes its (F, K)
    # feasibility mask as a bitmask and gathers, no per-task (P, F, K) sweep.
    pow2 = 2 ** jnp.arange(sys.num_versions)
    code = (feas_f * pow2[None, None]).sum(axis=-1)   # (M, F) subset codes
    rec_all_m = jnp.take_along_axis(
        prob.rec_table[None], code[:, None, :, None], axis=-1
    )[..., 0]                                         # (M, P, F)
    if warm_y is None:
        warm_y = -jnp.ones(feas_f.shape[0], jnp.int32)

    def per_task(feas_i, rec_all, warm_i):
        # any first-stage option with no feasible v is excluded from MP1
        fs_ok = feas_i.any(axis=-1)                      # (F,)

        # warm start: seed the scenario set with the warm y's worst pole and
        # start O_up at its robust cost (only when the warm start is usable)
        use_warm = (warm_i >= 0) & fs_ok[jnp.maximum(warm_i, 0)]
        wy = jnp.maximum(warm_i, 0)
        warm_pole = rec_all[:, wy].argmax()
        warm_up = c1[wy] + rec_all[warm_pole, wy]
        init_mask = jnp.zeros((prob.poles.shape[0],)).at[warm_pole].set(
            jnp.where(use_warm, 1.0, 0.0))
        init_up = jnp.where(use_warm, warm_up, BIG)

        def body(carry):
            it, scen_mask, o_up, _, y_best, done = carry
            # MP1: eta(y) = max over generated scenarios of the recourse value
            active = jnp.where(scen_mask[:, None] > 0, rec_all, -BIG)
            eta = jnp.where(scen_mask.sum() > 0, active.max(axis=0), 0.0)  # (F,)
            obj = jnp.where(fs_ok, c1 + eta, BIG)
            y_star = obj.argmin()
            o_down = obj[y_star]
            # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
            sp_vals = rec_all[:, y_star]                 # (P,)
            worst_pole = sp_vals.argmax()
            q = sp_vals[worst_pole]
            # the returned decision is the INCUMBENT achieving O_up, not the
            # last master argmin — the master's obj only lower-bounds the
            # robust cost, so a θ-tied y_star may be worse than the incumbent
            # (matters when the warm seed makes convergence fire early)
            y_best = jnp.where(c1[y_star] + q < o_up, y_star, y_best)
            o_up = jnp.minimum(o_up, c1[y_star] + q)
            done = (o_up - o_down) <= theta
            scen_mask = scen_mask.at[worst_pole].set(1.0)  # add scenario column
            return it + 1, scen_mask, o_up, o_down, y_best, done

        def cond(carry):
            it, _, _, _, _, done = carry
            return (it < max_iters) & ~done

        init = (0, init_mask, init_up, jnp.asarray(-BIG),
                wy, jnp.asarray(False))
        it, scen_mask, o_up, o_down, y_star, done = jax.lax.while_loop(cond, body, init)

        # final recourse: worst pole for chosen y, then v*
        sp_vals = rec_all[:, y_star]
        worst = sp_vals.argmax()
        u = prob.poles[worst] * prob.u_dev
        vals = jnp.where(feas_i[y_star], b2[y_star] * (1.0 + u), BIG)
        v_star = vals.argmin()
        return y_star, v_star, o_up, o_down, it

    y_f, v_star, o_up, o_down, iters = jax.vmap(per_task)(feas_f, rec_all_m, warm_y)
    # graceful margin relaxation: tasks infeasible *with* the robust margin
    # fall back to the max-accuracy configuration (which also covers margin-
    # free feasibility when any config clears A^q exactly)
    none_ok = ~feas_f.any(axis=(1, 2))
    best_acc = f_flat.reshape(f_flat.shape[0], -1).argmax(axis=1)
    ba_f = best_acc // sys.num_versions
    ba_v = best_acc % sys.num_versions
    y_f = jnp.where(none_ok, ba_f, y_f)
    v_star = jnp.where(none_ok, ba_v, v_star)
    route, r_idx, p_idx = lat.unflatten_index(y_f)
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


def solve_ccg_sharded(prob: RobustProblem, difficulty, acc_req, mesh,
                      axis: str = "data", max_iters: int = 8,
                      theta: float = 1e-4, warm_y=None):
    """``solve_ccg`` with the task batch M split across devices.

    The CCG sweep is embarrassingly parallel over tasks (the hoisted
    (P, F, K) recourse table is replicated; only the per-task feasibility
    masks and loop state are local), so a ``shard_map`` over the mesh's data
    axis scales the sweep linearly with device count.  The batch is padded to
    a multiple of the axis size with trivially-feasible dummies and sliced
    back, so any M works.  Decisions are identical to the single-device path.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import shard_map

    m = difficulty.shape[0]
    n_dev = mesh.shape[axis]
    pad = (-m) % n_dev
    difficulty = jnp.concatenate([difficulty, jnp.zeros((pad,), difficulty.dtype)])
    acc_req = jnp.concatenate([acc_req, jnp.zeros((pad,), acc_req.dtype)])
    if warm_y is None:
        warm_y = -jnp.ones((m,), jnp.int32)
    warm_y = jnp.concatenate([warm_y, -jnp.ones((pad,), jnp.int32)])

    def shard_fn(pb, z, aq, wy):
        return solve_ccg(pb, z, aq, max_iters=max_iters, theta=theta, warm_y=wy)

    # check_vma=False: the CCG while_loop has no replication rule, but every
    # operand is either axis-sharded or an explicitly replicated input
    sol = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis)),
        out_specs=P(axis), check_vma=False,
    )(prob, difficulty, acc_req, warm_y)
    return {k: v[:m] for k, v in sol.items()}


def exact_oracle(prob: RobustProblem, difficulty, acc_req):
    """Brute force min_y max_{u∈poles} min_v — test oracle."""
    lat = prob.lat
    c1 = lat.c1_flat
    b2 = lat.b2_flat
    _, feas_f = lat.feasible_flat(difficulty, acc_req, lat.sys.acc_margin_robust)

    def per_task(feas_i):
        u = prob.poles[:, None, :] * prob.u_dev        # (P, 1, K)
        vals = jnp.where(feas_i[None], b2[None] * (1.0 + u), BIG)  # (P, F, K)
        rec = vals.min(axis=-1)                         # (P, F)
        worst = rec.max(axis=0)                         # (F,)
        fs_ok = feas_i.any(axis=-1)
        obj = jnp.where(fs_ok, c1 + worst, BIG)
        y = obj.argmin()
        return y, obj[y]

    y, obj = jax.vmap(per_task)(feas_f)
    return y, obj


def total_cost(prob: RobustProblem, sol, difficulty, acc_req, u=None):
    """Realized cost of a solution under deviation u ((K,) or None=nominal)."""
    return prob.lat.solution_cost(sol, u=u)
