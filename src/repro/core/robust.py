"""Two-stage robust optimization (paper §3.1/§3.3, Eq. 2-10, Alg. 2).

Decision lattice per task: first stage y=(route∈{edge,cloud}, r∈R, p∈P)
(50 options), second stage v∈V (K=5 model versions).  The Γ-budget
polyhedral uncertainty set (Eq. 9)

    U = { u : u_k = g_k·ũ_k,  g_k∈[0,1],  Σ_k g_k ≤ Γ }

scales the second-stage cost of model k by (1+u_k) (compute-time deviation
under load/network fluctuation).  By Bertsimas-style strong duality the
worst-case u sits at a pole of U (Eq. 10), so SP is solved *exactly* by pole
enumeration (K=5 ⇒ 2^K = 32 subset poles, filtered to |S| ≤ Γ), and the
column-and-constraint master (Alg. 2) alternates:

    MP1 : y* = argmin_y c1(y) + η(y),  η(y) = max over generated scenarios
          of the recourse value  min_v b2(v; y)·(1+u_j,v)
    SP  : u_{j+1} = argmax_{u∈poles} min_{v feasible} b2(v; y*)·(1+u_v)

until O_up − O_down ≤ θ.  Everything is vectorized over tasks with vmap;
``exact_oracle`` brute-forces min_y max_u min_v for tests.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables

BIG = 1e9


def _poles(num_versions: int, gamma: int):
    """All subset poles of U with |S| <= gamma: (P, K) in {0,1}."""
    k = num_versions
    masks = []
    for bits in range(2 ** k):
        s = [(bits >> i) & 1 for i in range(k)]
        if sum(s) <= gamma:
            masks.append(s)
    return jnp.asarray(masks, jnp.float32)  # (P, K)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("c1", "b2", "poles", "u_dev"),
    meta_fields=("sys",),
)
@dataclasses.dataclass(frozen=True)
class RobustProblem:
    sys: SystemConfig
    c1: jnp.ndarray        # (N, Z, 2) first-stage cost
    b2: jnp.ndarray        # (N, Z, K, 2) second-stage nominal cost
    poles: jnp.ndarray     # (P, K) pole indicators
    u_dev: jnp.ndarray     # (K,) max deviations ũ_k

    @classmethod
    def build(cls, sys: SystemConfig):
        c1, b2, _ = cost_tables(sys)
        poles = _poles(sys.num_versions, sys.gamma)
        # deviation grows with model size (bigger models queue worse)
        u_dev = sys.u_dev * (0.6 + 0.4 * jnp.arange(sys.num_versions) / (sys.num_versions - 1))
        return cls(sys=sys, c1=c1, b2=b2, poles=poles, u_dev=u_dev)


def recourse_value(prob: RobustProblem, feas, b2_yrp, pole):
    """min_v (1+u_v)·b2_v over feasible v for one pole. b2_yrp: (K,)."""
    u = pole * prob.u_dev
    vals = jnp.where(feas, b2_yrp * (1.0 + u), BIG)
    return vals.min(), vals.argmin()


@partial(jax.jit, static_argnames=("max_iters",))
def solve_ccg(prob: RobustProblem, difficulty, acc_req, max_iters: int = 8, theta: float = 1e-4):
    """Alg. 2 for a batch of tasks.

    difficulty: (M,) content difficulty z; acc_req: (M,) A^q_i.
    Returns dict with y (route), r, p, v indices + objective bounds.
    """
    sys = prob.sys
    f = accuracy_table(sys, difficulty)              # (M, N, Z, K, 2)
    # C1 protected with the robust accuracy margin (h in the Benders cuts)
    feas = f >= (acc_req + sys.acc_margin_robust)[:, None, None, None, None]
    # cost arranged per first-stage option (N*Z*2) x versions
    c1 = prob.c1.transpose(2, 0, 1).reshape(-1)       # (F,) F = 2*N*Z
    b2 = prob.b2.transpose(3, 0, 1, 2).reshape(-1, sys.num_versions)  # (F, K)
    feas_f = feas.transpose(0, 4, 1, 2, 3).reshape(feas.shape[0], -1, sys.num_versions)

    def per_task(feas_i):
        # any first-stage option with no feasible v is excluded from MP1
        fs_ok = feas_i.any(axis=-1)                      # (F,)

        def pole_recourse(u_mask, y_all=True):
            u = u_mask * prob.u_dev                      # (K,)
            vals = jnp.where(feas_i, b2 * (1.0 + u), BIG)  # (F, K)
            return vals.min(axis=-1)                     # (F,)

        # worst-case over ALL poles for every F (used for oracle + SP)
        rec_all = jax.vmap(pole_recourse)(prob.poles)    # (P, F)

        def body(carry):
            it, scen_mask, o_up, _, _, done = carry
            # MP1: eta(y) = max over generated scenarios of the recourse value
            active = jnp.where(scen_mask[:, None] > 0, rec_all, -BIG)
            eta = jnp.where(scen_mask.sum() > 0, active.max(axis=0), 0.0)  # (F,)
            obj = jnp.where(fs_ok, c1 + eta, BIG)
            y_star = obj.argmin()
            o_down = obj[y_star]
            # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
            sp_vals = rec_all[:, y_star]                 # (P,)
            worst_pole = sp_vals.argmax()
            q = sp_vals[worst_pole]
            o_up = jnp.minimum(o_up, c1[y_star] + q)
            done = (o_up - o_down) <= theta
            scen_mask = scen_mask.at[worst_pole].set(1.0)  # add scenario column
            return it + 1, scen_mask, o_up, o_down, y_star, done

        def cond(carry):
            it, _, _, _, _, done = carry
            return (it < max_iters) & ~done

        p = prob.poles.shape[0]
        init = (0, jnp.zeros((p,)), jnp.asarray(BIG), jnp.asarray(-BIG),
                jnp.asarray(0, dtype=jnp.int32), jnp.asarray(False))
        it, scen_mask, o_up, o_down, y_star, done = jax.lax.while_loop(cond, body, init)

        # final recourse: worst pole for chosen y, then v*
        sp_vals = rec_all[:, y_star]
        worst = sp_vals.argmax()
        u = prob.poles[worst] * prob.u_dev
        vals = jnp.where(feas_i[y_star], b2[y_star] * (1.0 + u), BIG)
        v_star = vals.argmin()
        return y_star, v_star, o_up, o_down, it

    y_f, v_star, o_up, o_down, iters = jax.vmap(per_task)(feas_f)
    # graceful margin relaxation: tasks infeasible *with* the robust margin
    # fall back to the max-accuracy configuration (which also covers margin-
    # free feasibility when any config clears A^q exactly)
    none_ok = ~feas_f.any(axis=(1, 2))
    f_flat = f.transpose(0, 4, 1, 2, 3).reshape(f.shape[0], -1)
    best_acc = f_flat.argmax(axis=1)
    ba_f = best_acc // sys.num_versions
    ba_v = best_acc % sys.num_versions
    y_f = jnp.where(none_ok, ba_f, y_f)
    v_star = jnp.where(none_ok, ba_v, v_star)
    # unflatten first-stage index F = 2*N*Z -> (route, r, p)
    nz = sys.n_res * sys.n_fps
    route = y_f // nz
    rp = y_f % nz
    r_idx = rp // sys.n_fps
    p_idx = rp % sys.n_fps
    return {
        "route": route, "r": r_idx, "p": p_idx, "v": v_star,
        "o_up": o_up, "o_down": o_down, "iters": iters, "infeasible": none_ok,
    }


def exact_oracle(prob: RobustProblem, difficulty, acc_req):
    """Brute force min_y max_{u∈poles} min_v — test oracle."""
    sys = prob.sys
    f = accuracy_table(sys, difficulty)
    feas = f >= (acc_req + sys.acc_margin_robust)[:, None, None, None, None]
    c1 = prob.c1.transpose(2, 0, 1).reshape(-1)
    b2 = prob.b2.transpose(3, 0, 1, 2).reshape(-1, sys.num_versions)
    feas_f = feas.transpose(0, 4, 1, 2, 3).reshape(feas.shape[0], -1, sys.num_versions)

    def per_task(feas_i):
        u = prob.poles[:, None, :] * prob.u_dev        # (P, 1, K)
        vals = jnp.where(feas_i[None], b2[None] * (1.0 + u), BIG)  # (P, F, K)
        rec = vals.min(axis=-1)                         # (P, F)
        worst = rec.max(axis=0)                         # (F,)
        fs_ok = feas_i.any(axis=-1)
        obj = jnp.where(fs_ok, c1 + worst, BIG)
        y = obj.argmin()
        return y, obj[y]

    y, obj = jax.vmap(per_task)(feas_f)
    return y, obj


def total_cost(prob: RobustProblem, sol, difficulty, acc_req, u=None):
    """Realized cost of a solution under deviation u ((K,) or None=nominal)."""
    sys = prob.sys
    route, r, p, v = sol["route"], sol["r"], sol["p"], sol["v"]
    c1 = prob.c1[r, p, route]
    b = prob.b2[r, p, v, route]
    if u is not None:
        b = b * (1.0 + u[v])
    return c1 + b
