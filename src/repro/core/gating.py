"""Temporal gating unit (paper §3.2, Eq. 5-6).

Gated recurrent cell with *content-adaptive forget bias*:

    g_t = σ( W_g Δx_t + U_g h_{t-1} + b_g + α · Var(Δx_{t-T:t}) )      (5)
    r_t = σ( W_r Δx_t + U_r h_{t-1} + b_r )
    h_t = (1-g_t) ⊙ h_{t-1} + g_t ⊙ tanh( W_h Δx_t + U_h (r_t ⊙ h_{t-1}) + b_h )  (6)
    τ_t = σ( W_o h_t + b_o ) ∈ [0,1]      — temporal significance score

The volatility term α·Var(Δx_{t-T:t}) opens the gate aggressively when
recent motion variance spikes (missed-critical-event protection).  Also
provided as a fused Pallas TPU kernel in repro.kernels.temporal_gate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.temporal_gate.ops import gate_cell
from repro.models.params import ParamSpec


@dataclasses.dataclass(frozen=True)
class GateConfig:
    d_feature: int
    d_hidden: int = 32
    var_window: int = 8          # T in Eq. (5)
    alpha_init: float = 1.0
    # every how many steps the batched gate recomputes its running Σ/Σ² from
    # the exact ring buffer (bounds float32 drift of the incremental
    # volatility).  0 = once per window (var_window); 1 = every step (the
    # incremental sums are then always exact, matching the looped oracle).
    resync_period: int = 0


def gate_specs(cfg: GateConfig) -> dict:
    d, m = cfg.d_feature, cfg.d_hidden
    sd, sm = d ** -0.5, m ** -0.5
    return {
        "w_g": ParamSpec((d, m), (None, None), stddev=sd),
        "u_g": ParamSpec((m, m), (None, None), stddev=sm),
        "b_g": ParamSpec((m,), (None,), init="zeros"),
        "alpha": ParamSpec((), (), init="ones"),
        "w_r": ParamSpec((d, m), (None, None), stddev=sd),
        "u_r": ParamSpec((m, m), (None, None), stddev=sm),
        "b_r": ParamSpec((m,), (None,), init="zeros"),
        "w_h": ParamSpec((d, m), (None, None), stddev=sd),
        "u_h": ParamSpec((m, m), (None, None), stddev=sm),
        "b_h": ParamSpec((m,), (None,), init="zeros"),
        "w_o": ParamSpec((m, 1), (None, None), stddev=sm),
        "b_o": ParamSpec((1,), (None,), init="zeros"),
    }


class GateState(NamedTuple):
    h: jnp.ndarray          # (m,) hidden
    var_buf: jnp.ndarray    # (T, d) recent Δx ring buffer
    var_idx: jnp.ndarray    # scalar int32


def init_state(cfg: GateConfig) -> GateState:
    return GateState(
        h=jnp.zeros((cfg.d_hidden,), jnp.float32),
        var_buf=jnp.zeros((cfg.var_window, cfg.d_feature), jnp.float32),
        var_idx=jnp.zeros((), jnp.int32),
    )


def gate_step(cfg: GateConfig, p, state: GateState, dx):
    """One recurrence step. dx: (d,). Returns (new_state, (tau, g_mean))."""
    buf = jax.lax.dynamic_update_slice_in_dim(
        state.var_buf, dx[None], jnp.mod(state.var_idx, cfg.var_window), axis=0
    )
    # volatility over the last T frames (scalar: mean feature variance)
    vol = jnp.var(buf, axis=0).mean()

    g = jax.nn.sigmoid(dx @ p["w_g"] + state.h @ p["u_g"] + p["b_g"] + p["alpha"] * vol)
    r = jax.nn.sigmoid(dx @ p["w_r"] + state.h @ p["u_r"] + p["b_r"])
    cand = jnp.tanh(dx @ p["w_h"] + (r * state.h) @ p["u_h"] + p["b_h"])
    h = (1.0 - g) * state.h + g * cand
    tau = jax.nn.sigmoid(h @ p["w_o"] + p["b_o"])[0]
    new_state = GateState(h=h, var_buf=buf, var_idx=state.var_idx + 1)
    return new_state, (tau, g.mean())


# ---------------------------------------------------------------------------
# Fused batched streaming step (the serving hot path)
#
# ``gate_step`` re-scans the whole (T, d) ring buffer every step to get the
# volatility Var(Δx_{t-T:t}); at fleet scale that is an O(T·d) read per
# stream per tick.  The batched state below carries running Σx / Σx² over the
# buffer instead, so each step is O(d): subtract the evicted frame, add the
# new one.  The six-matmul cell itself dispatches to the fused Pallas
# ``gate_cell`` on TPU (pure-jnp ref elsewhere) — one VMEM-resident pass for
# the whole (M, d) stream batch.
# ---------------------------------------------------------------------------
class GateBatchState(NamedTuple):
    h: jnp.ndarray          # (M, m) hidden
    var_buf: jnp.ndarray    # (M, T, d) Δx ring buffer (holds the evictees)
    var_idx: jnp.ndarray    # (M,) int32
    var_sum: jnp.ndarray    # (M, d) running Σ Δx over the buffer
    var_sumsq: jnp.ndarray  # (M, d) running Σ Δx² over the buffer


def init_batch_state(cfg: GateConfig, n_streams: int) -> GateBatchState:
    return GateBatchState(
        h=jnp.zeros((n_streams, cfg.d_hidden), jnp.float32),
        var_buf=jnp.zeros((n_streams, cfg.var_window, cfg.d_feature), jnp.float32),
        var_idx=jnp.zeros((n_streams,), jnp.int32),
        var_sum=jnp.zeros((n_streams, cfg.d_feature), jnp.float32),
        var_sumsq=jnp.zeros((n_streams, cfg.d_feature), jnp.float32),
    )


def gate_step_batch(cfg: GateConfig, p, state: GateBatchState, dx, *,
                    force: str = "auto"):
    """One fused recurrence step for all streams. dx: (M, d).

    Returns ``(new_state, (tau (M,), g_mean (M,)))`` — the batched equivalent
    of ``vmap(gate_step)`` with the volatility maintained incrementally.
    """
    t = cfg.var_window
    slot = jnp.mod(state.var_idx, t)                              # (M,)
    old = jnp.take_along_axis(state.var_buf, slot[:, None, None], axis=1)[:, 0]
    var_sum = state.var_sum + dx - old                            # (M, d)
    var_sumsq = state.var_sumsq + dx * dx - old * old
    hit = jnp.arange(t)[None, :] == slot[:, None]                 # (M, T)
    buf = jnp.where(hit[:, :, None], dx[:, None, :], state.var_buf)
    # resync the running sums against the exact ring buffer on a configured
    # cadence (default: once per window): the incremental updates random-walk
    # float32 rounding error over long serving runs; the buffer is exact, so
    # this bounds the drift to ``resync_period`` steps at an amortized O(d)
    # cost (streams advance in lockstep, and if they don't, an off-phase
    # resync is still exact).  lax.cond keeps the (T, d) reduction off the
    # trace-hot path on non-resync steps.
    period = cfg.resync_period or t
    var_sum, var_sumsq = jax.lax.cond(
        (state.var_idx[0] + 1) % period == 0,
        lambda: (buf.sum(axis=1), jnp.square(buf).sum(axis=1)),
        lambda: (var_sum, var_sumsq),
    )
    mean = var_sum / t
    vol = jnp.maximum(var_sumsq / t - mean * mean, 0.0).mean(axis=-1)  # (M,)

    h, tau, g_mean = gate_cell(dx, state.h, vol, p, force=force)
    new_state = GateBatchState(
        h=h, var_buf=buf, var_idx=state.var_idx + 1,
        var_sum=var_sum, var_sumsq=var_sumsq,
    )
    return new_state, (tau, g_mean)


def gate_scan(cfg: GateConfig, p, dxs, state: GateState | None = None):
    """dxs: (T, d) -> (taus (T,), gate_means (T,), final_state)."""
    if state is None:
        state = init_state(cfg)

    def body(s, dx):
        s, out = gate_step(cfg, p, s, dx)
        return s, out

    final, (taus, gs) = jax.lax.scan(body, state, dxs)
    return taus, gs, final


def gate_scan_batch(cfg: GateConfig, p, dxs, states=None):
    """dxs: (B, T, d) — vmapped over streams."""
    if states is None:
        states = jax.vmap(lambda _: init_state(cfg))(jnp.arange(dxs.shape[0]))
    return jax.vmap(lambda d, s: gate_scan(cfg, p, d, s))(dxs, states)


def gate_window_scan(cfg: GateConfig, p, dxs, state: GateBatchState | None = None,
                     *, force: str = "auto"):
    """dxs: (M, T, d) -> (taus (M, T), gate_means (M, T), final_state).

    Time-scan of the fused batched streaming step — the whole stream batch
    advances one segment per scan tick through ``gate_step_batch``, so the
    windowed API shares the streaming path's kernel dispatch and O(d)
    incremental volatility instead of vmapping a per-stream ``lax.scan``
    (``gate_scan_batch``, kept for ``gate_loss`` training).
    """
    if state is None:
        state = init_batch_state(cfg, dxs.shape[0])

    def body(s, dx):
        s, out = gate_step_batch(cfg, p, s, dx, force=force)
        return s, out

    final, (taus, gs) = jax.lax.scan(body, state, jnp.moveaxis(dxs, 1, 0))
    return taus.T, gs.T, final


# ---------------------------------------------------------------------------
# Meta-training (offline warm-up): L = L_acc + λ1·L_lat + λ2·L_comp
#   L_acc : BCE of τ against the oracle cloud-benefit label
#   L_lat : mean τ      (cloud offloads cost latency)
#   L_comp: mean gate   (gate openness costs compute)
# Online fine-tuning adds a proximal term μ/2 ||θ - θ_offline||² against
# catastrophic forgetting (paper §3.2).
# ---------------------------------------------------------------------------
def gate_loss(cfg: GateConfig, p, dxs, benefit_labels, lam1=0.05, lam2=0.01,
              anchor=None, mu=0.0):
    taus, gs, _ = gate_scan_batch(cfg, p, dxs)
    eps = 1e-6
    bce = -(benefit_labels * jnp.log(taus + eps)
            + (1 - benefit_labels) * jnp.log(1 - taus + eps)).mean()
    l_lat = taus.mean()
    l_comp = gs.mean()
    loss = bce + lam1 * l_lat + lam2 * l_comp
    if anchor is not None and mu > 0:
        prox = sum(
            jnp.sum(jnp.square(a - b))
            for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(anchor))
        )
        loss = loss + 0.5 * mu * prox
    return loss, {"bce": bce, "l_lat": l_lat, "l_comp": l_comp}
