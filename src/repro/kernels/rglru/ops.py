"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru.kernel import rglru_scan as _pallas
from repro.kernels.rglru.ref import rglru_scan_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_t", "block_w", "force"))
def rglru_scan(x, rgate, igate, log_a_base, h0=None, *, block_t: int = 128,
               block_w: int = 512, force: str = "auto"):
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if use_pallas:
        return _pallas(x, rgate, igate, log_a_base, h0, block_t=block_t,
                       block_w=block_w, interpret=not _on_tpu())
    return _ref(x, rgate, igate, log_a_base, h0)
