"""Pallas TPU blocked RG-LRU linear recurrence (RecurrentGemma mixer).

Same tiling strategy as the mamba kernel: grid = (B, n_w, n_t), time
innermost, per-channel hidden state (BW,) carried in VMEM scratch across
time tiles.  The per-step work is pure VPU elementwise math over the
channel-block lanes; HBM traffic = read x/r/i once + write y once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, r_ref, i_ref, la_ref, h0_ref, y_ref, hout_ref, h_scr,
                  *, bt, nt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    x = x_ref[0].astype(jnp.float32)      # (BT, BW)
    r = r_ref[0].astype(jnp.float32)
    gi = i_ref[0].astype(jnp.float32)
    la = la_ref[...].astype(jnp.float32)  # (BW,)

    def step(t, h):
        a = jnp.exp(la * r[t])
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (gi[t] * x[t])
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h_final = jax.lax.fori_loop(0, bt, step, h_scr[...])
    h_scr[...] = h_final

    @pl.when(ti == nt - 1)
    def _finalize():
        hout_ref[0] = h_scr[...]


def rglru_scan(x, rgate, igate, log_a_base, h0=None, *, block_t: int = 128,
               block_w: int = 512, interpret: bool = False):
    """x, rgate, igate: (B, S, W); log_a_base: (W,)."""
    b, s, w = x.shape
    bt = min(block_t, s)
    bw = min(block_w, w)
    assert s % bt == 0 and w % bw == 0
    nt, nw = s // bt, w // bw
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    kernel = functools.partial(_rglru_kernel, bt=bt, nt=nt)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(b, nw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((bw,), lambda bi, wi, ti: (wi,)),
            pl.BlockSpec((1, bw), lambda bi, wi, ti: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, ti: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(x, rgate, igate, log_a_base, h0)
    return y, h_out
