"""Pure-jnp oracle for the blocked RG-LRU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(x, rgate, igate, log_a_base, h0=None):
    """x, rgate, igate: (B, S, W) f32; log_a_base: (W,) <= 0.

    a_t = exp(log_a_base ⊙ r_t);  h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)
    """
    b, s, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def body(h, inp):
        x_t, r_t, i_t = inp
        a = jnp.exp(log_a_base[None] * r_t)
        h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i_t * x_t)
        return h_new, h_new

    xs = tuple(jnp.moveaxis(v.astype(jnp.float32), 1, 0) for v in (x, rgate, igate))
    h_final, ys = jax.lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final
