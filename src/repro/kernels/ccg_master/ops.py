"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ccg_master.kernel import ccg_master as _pallas
from repro.kernels.ccg_master.ref import ccg_master_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_m", "block_f", "force"))
def ccg_master(rec_all, scen_mask, fs_ok, c1, *, block_m: int = 128,
               block_f: int = 128, force: str = "auto"):
    """Masked CCG master step for a task batch -> (y_star, o_down).

    rec_all: (M, P, F); scen_mask: (M, P) 0/1; fs_ok: (M, F) bool; c1: (F,).
    ``force``: "auto" picks Pallas on TPU and the jnp ref elsewhere;
    "pallas"/"ref" override (Pallas runs in interpret mode off-TPU).  Both
    M and F are padded up to the kernel blocks, so any shape works: padded
    options are infeasible (they never win the argmin) and padded tasks are
    sliced off.
    """
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return _ref(rec_all, scen_mask, fs_ok, c1)
    m, p, f = rec_all.shape
    bm = min(block_m, m)
    bf = min(block_f, f)
    pad_m = (-m) % bm
    pad_f = (-f) % bf
    if pad_m or pad_f:
        rec_all = jnp.pad(rec_all, ((0, pad_m), (0, 0), (0, pad_f)))
        scen_mask = jnp.pad(scen_mask, ((0, pad_m), (0, 0)))
        fs_ok = jnp.pad(fs_ok, ((0, pad_m), (0, pad_f)))
        c1 = jnp.pad(c1, (0, pad_f))
    y, o_down = _pallas(
        rec_all.astype(jnp.float32),
        scen_mask.astype(jnp.float32),
        fs_ok.astype(jnp.float32),
        c1.astype(jnp.float32),
        block_m=bm, block_f=bf, interpret=not _on_tpu(),
    )
    return y[:m], o_down[:m]
