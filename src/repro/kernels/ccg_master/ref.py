"""Pure-jnp oracle for the CCG master step (paper Alg. 2, MP1).

This is exactly the reduction the robust solver's master problem performs
every CCG iteration: the scenario-masked recourse maximum η(y), the
feasibility-masked objective c1 + η, and its argmin over the F first-stage
options.  The Pallas kernel and the unrolled solver both must reproduce it
bit-for-bit (argmin ties break to the lowest flat index).
"""
from __future__ import annotations

import jax.numpy as jnp

# infeasible-option sentinel shared by the kernel, this ref, and the solver
# in repro.core.robust (which imports it) — one definition keeps the
# infeasible-lane/argmin bit-parity contract in one place
BIG = 1e9


def ccg_master_ref(rec_all, scen_mask, fs_ok, c1):
    """One masked MP1 step for a task batch.

    rec_all: (M, P, F) per-task recourse values of every pole/option pair;
    scen_mask: (M, P) 0/1 generated-scenario indicators; fs_ok: (M, F) bool
    first-stage feasibility; c1: (F,) first-stage cost.  Returns
    ``(y_star (M,) int32, o_down (M,))`` — the master argmin and its value
    (the CCG lower bound).  Tasks with an empty scenario set get η = 0 (the
    cold-start master is first-stage-cost-only); infeasible options score BIG.
    """
    active = jnp.where(scen_mask[..., None] > 0, rec_all, -BIG)
    any_scen = scen_mask.sum(axis=-1, keepdims=True) > 0
    eta = jnp.where(any_scen, active.max(axis=-2), 0.0)        # (M, F)
    obj = jnp.where(fs_ok, c1 + eta, BIG)
    y_star = obj.argmin(axis=-1)
    o_down = jnp.take_along_axis(obj, y_star[..., None], axis=-1)[..., 0]
    return y_star.astype(jnp.int32), o_down
