"""Pallas TPU kernel for the CCG master step (paper Alg. 2, MP1).

The unrolled robust solver runs this reduction once per CCG iteration for
the whole task batch: mask the (P, F) recourse slab by the generated
scenarios, take the max over poles (η), add the first-stage cost, mask
infeasible options to BIG, and argmin over F.  XLA executes that as four
separate HBM-bound elementwise/reduce ops over the (M, P, F) slab; here the
slab tile stays VMEM-resident and the whole chain runs in one pass.

Grid = (n_m, n_f) with F innermost: each (bm, P, bf) tile folds its local
min/argmin into the running per-task best, so the argmin streams over F
tiles without materializing the (M, F) objective.  Ties break to the lowest
flat index (strict-< across tiles, first-min within a tile), matching
``jnp.argmin``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ccg_master.ref import BIG

_INT_MAX = jnp.iinfo(jnp.int32).max


def _master_kernel(rec_ref, mask_ref, fsok_ref, c1_ref, y_ref, od_ref):
    fi = pl.program_id(1)
    bm, _, bf = rec_ref.shape

    mask = mask_ref[...]                                   # (bm, P)
    any_scen = mask.sum(axis=1) > 0.0                      # (bm,)
    active = jnp.where(mask[:, :, None] > 0.0, rec_ref[...], -BIG)
    eta = jnp.where(any_scen[:, None], active.max(axis=1), 0.0)   # (bm, bf)
    obj = jnp.where(fsok_ref[...] > 0.0, c1_ref[...][None, :] + eta, BIG)

    # first-min argmin for this tile, in global F coordinates
    idx = jax.lax.broadcasted_iota(jnp.int32, (bm, bf), 1) + fi * bf
    tile_min = obj.min(axis=1)                             # (bm,)
    tile_arg = jnp.where(obj == tile_min[:, None], idx, _INT_MAX).min(axis=1)

    @pl.when(fi == 0)
    def _():
        od_ref[...] = jnp.full((bm,), BIG, od_ref.dtype)
        y_ref[...] = jnp.zeros((bm,), y_ref.dtype)

    best = od_ref[...]
    better = tile_min < best                               # strict: first min wins
    od_ref[...] = jnp.where(better, tile_min, best)
    y_ref[...] = jnp.where(better, tile_arg, y_ref[...])


def ccg_master(rec_all, scen_mask, fs_ok, c1, *, block_m: int = 128,
               block_f: int = 128, interpret: bool = False):
    """rec_all: (M, P, F); scen_mask: (M, P); fs_ok: (M, F) float 0/1;
    c1: (F,) -> (y_star (M,) int32, o_down (M,) float32).

    M must divide block_m and F divide block_f (the ops wrapper pads).
    """
    m, p, f = rec_all.shape
    bm = min(block_m, m)
    bf = min(block_f, f)
    assert m % bm == 0 and f % bf == 0
    grid = (m // bm, f // bf)

    return pl.pallas_call(
        _master_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, p, bf), lambda mi, fi: (mi, 0, fi)),
            pl.BlockSpec((bm, p), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((bm, bf), lambda mi, fi: (mi, fi)),
            pl.BlockSpec((bf,), lambda mi, fi: (fi,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda mi, fi: (mi,)),
            pl.BlockSpec((bm,), lambda mi, fi: (mi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(rec_all, scen_mask, fs_ok, c1)
