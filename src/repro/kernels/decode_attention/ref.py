"""Pure-jnp oracle for single-token KV-cache decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (B, H, D); k/v_cache: (B, KV, S, D); length: (B,) valid entries.

    Returns (B, H, D).  fp32 softmax; positions >= length are masked.
    """
    b, h, d = q.shape
    _, kv, s, _ = k_cache.shape
    g = h // kv
    qg = q.reshape(b, kv, g, d)
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * (d ** -0.5)
    valid = jnp.arange(s)[None, :] < length[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
