"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention as _pallas
from repro.kernels.decode_attention.ref import decode_attention_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_s", "force"))
def decode_attention(q, k_cache, v_cache, length, *, block_s: int = 256,
                     force: str = "auto"):
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if use_pallas:
        return _pallas(q, k_cache, v_cache, length, block_s=block_s,
                       interpret=not _on_tpu())
    return _ref(q, k_cache, v_cache, length)
