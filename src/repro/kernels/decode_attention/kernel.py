"""Pallas TPU decode attention: one query token vs. a long KV cache.

This is the serving hot loop (decode_32k / long_500k shapes): arithmetic
intensity is O(1) FLOP/byte, so the kernel's job is to stream the cache
through VMEM at full HBM bandwidth while keeping the online-softmax state
resident.  Grid = (B*KV, ns) with the cache-sequence dimension innermost;
per step we load a (Bs, D) cache tile, accumulate (G, Bs) scores for the
whole GQA group (rows of the MXU), and fold into the running (m, l, acc).
The valid-length mask comes from a scalar-prefetch operand so tiles beyond
``length`` are skipped without reading them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, bs, ns, scale):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    s_start = si * bs

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0]                     # (G, D)
        k = k_ref[0]                     # (Bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                         # (G, Bs)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, length, *, block_s: int = 256,
                     interpret: bool = False):
    """q: (B, H, D); caches: (B, KV, S, D); length: (B,) int32 -> (B, H, D)."""
    b, h, d = q.shape
    _, kv, s, _ = k_cache.shape
    g = h // kv
    bs = min(block_s, s)
    assert s % bs == 0
    ns = s // bs
    qg = q.reshape(b * kv, g, d)
    kg = k_cache.reshape(b * kv, s, d)
    vg = v_cache.reshape(b * kv, s, d)
    len_per_bh = jnp.repeat(length.astype(jnp.int32), kv)

    kernel = functools.partial(_decode_kernel, bs=bs, ns=ns, scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, si: (bh,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, bs, d), lambda bh, si: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(len_per_bh, qg, kg, vg)
    return out.reshape(b, h, d)
