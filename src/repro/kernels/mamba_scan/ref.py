"""Pure-jnp oracle for the blocked Mamba-1 selective scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, B, C, A, D, h0=None):
    """x, dt: (b, S, Di); B, C: (b, S, N); A: (Di, N); D: (Di,).

    h_t = exp(dt_t·A) ⊙ h_{t-1} + dt_t·B_t·x_t ;  y_t = C_t·h_t + D ⊙ x_t
    Returns (y (b, S, Di) f32, h_final (b, Di, N) f32).
    """
    b, s, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def body(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * A[None])
        h_new = da * h + dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        y_t = jnp.einsum("bdn,bn->bd", h_new, c_t) + D[None] * x_t
        return h_new, y_t

    xs = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (x, dt, B, C)
    )
    h_final, ys = jax.lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final
