"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba_scan.kernel import selective_scan as _pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_t", "block_d", "force"))
def selective_scan(x, dt, B, C, A, D, h0=None, *, block_t: int = 128,
                   block_d: int = 512, force: str = "auto"):
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if use_pallas:
        return _pallas(x, dt, B, C, A, D, h0, block_t=block_t, block_d=block_d,
                       interpret=not _on_tpu())
    return _ref(x, dt, B, C, A, D, h0)
