"""Pallas TPU blocked selective scan (Mamba-1 mixer).

The XLA fallback is a per-token lax.scan whose (B, Di, N) state round-trips
HBM every step — 64 layers x 4096 steps of ~MB-sized traffic (the dominant
memory-roofline term for falcon-mamba, see EXPERIMENTS.md §Perf).  This
kernel processes the time axis in VMEM tiles: grid = (b, n_di, n_t) with the
time dimension innermost; the (BD, N) state lives in VMEM scratch across
time tiles, so HBM traffic collapses to "read x/dt/B/C once, write y once".

Within a tile the recurrence is a fori_loop over BT steps on registers/VMEM;
the channel block BD (lanes) is vectorized on the VPU.  d_state N=16 rides
in the sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                  y_ref, hout_ref, h_scr, *, bt, nt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    x = x_ref[0].astype(jnp.float32)     # (BT, BD)
    dt = dt_ref[0].astype(jnp.float32)   # (BT, BD)
    bm = b_ref[0].astype(jnp.float32)    # (BT, N)
    cm = c_ref[0].astype(jnp.float32)    # (BT, N)
    a = a_ref[...].astype(jnp.float32)   # (BD, N)
    d = d_ref[...].astype(jnp.float32)   # (BD,)

    def step(t, carry):
        h = carry                         # (BD, N)
        da = jnp.exp(dt[t][:, None] * a)
        h = da * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        y_t = jnp.sum(h * cm[t][None, :], axis=-1) + d * x[t]
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_final = jax.lax.fori_loop(0, bt, step, h_scr[...])
    h_scr[...] = h_final

    @pl.when(ti == nt - 1)
    def _finalize():
        hout_ref[0] = h_scr[...]


def selective_scan(x, dt, B, C, A, D, h0=None, *, block_t: int = 128,
                   block_d: int = 512, interpret: bool = False):
    """x, dt: (b, S, Di); B, C: (b, S, N); A: (Di, N); D: (Di,)."""
    b, s, di = x.shape
    n = A.shape[1]
    bt = min(block_t, s)
    bd = min(block_d, di)
    assert s % bt == 0 and di % bd == 0
    nt, nd = s // bt, di // bd
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    kernel = functools.partial(_mamba_kernel, bt=bt, nt=nt)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(b, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda bi, dii, ti: (bi, ti, dii)),  # x
            pl.BlockSpec((1, bt, bd), lambda bi, dii, ti: (bi, ti, dii)),  # dt
            pl.BlockSpec((1, bt, n), lambda bi, dii, ti: (bi, ti, 0)),     # B
            pl.BlockSpec((1, bt, n), lambda bi, dii, ti: (bi, ti, 0)),     # C
            pl.BlockSpec((bd, n), lambda bi, dii, ti: (dii, 0)),           # A
            pl.BlockSpec((bd,), lambda bi, dii, ti: (dii,)),               # D
            pl.BlockSpec((1, bd, n), lambda bi, dii, ti: (bi, dii, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda bi, dii, ti: (bi, ti, dii)),
            pl.BlockSpec((1, bd, n), lambda bi, dii, ti: (bi, dii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, D, h0)
    return y, h_out
