"""Pallas TPU flash attention: GQA, causal, optional sliding window.

Tiling: grid = (B*KV, nq, nk) with the k dimension innermost (sequential on
TPU), so the online-softmax state (m, l, acc) lives in VMEM scratch across k
steps.  The q tile is (G*Bq, D) — the GQA group is folded into MXU rows so
even kv=1 (MQA) archs fill the systolic array.  Fully-masked k tiles
(above the causal diagonal / outside the window) are skipped with pl.when.

Block sizes default to (128, 128): the VMEM working set is
  q (G*Bq, D) + k/v (Bk, D) + acc (G*Bq, D) f32 + scores (G*Bq, Bk) f32
~= 8·128·128·(2+2+2+4+4) bytes ≈ 1.8 MB for G=8, comfortably inside the
16 MB VMEM budget, and every matmul dim is a multiple of the 128-lane MXU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, bq, bk, nk, window, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    def _compute():
        q = q_ref[0]                      # (G, Bq, D) -> fold G
        g, _, d = q.shape
        q2 = q.reshape(g * bq, d)
        k = k_ref[0]                      # (Bk, D)
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                          # (G*Bq, Bk)
        # rows are (g, bq) flattened; the token position depends on row % bq
        row = jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 0)
        q_pos = q_start + jnp.remainder(row, bq)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    # skip tiles that are fully masked
    live = True
    if causal:
        live = q_start + bq - 1 >= k_start
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)
    pl.when(live)(_compute)

    @pl.when(ki == nk - 1)
    def _finalize():
        g = q_ref.shape[1]
        d = acc_scr.shape[-1]
        l = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / l[:, None]).reshape(g, bq, d)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, window: Optional[int] = None, causal: bool = True,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = d ** -0.5

    qg = q.reshape(b, kv, g, sq, d).reshape(b * kv, g, sq, d)
    kg = k.reshape(b * kv, sk, d)
    vg = v.reshape(b * kv, sk, d)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, window=window, causal=causal, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, bq, d), lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, bq, d), lambda bh, qi, ki: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, kv, g, sq, d).reshape(b, h, sq, d)
