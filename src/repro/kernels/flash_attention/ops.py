"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import attention_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window", "causal", "block_q", "block_k", "force"))
def flash_attention(q, k, v, *, window: Optional[int] = None, causal: bool = True,
                    block_q: int = 128, block_k: int = 128, force: str = "auto"):
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if use_pallas:
        return _pallas(q, k, v, window=window, causal=causal,
                       block_q=block_q, block_k=block_k,
                       interpret=not _on_tpu())
    return _ref(q, k, v, window=window, causal=causal)
