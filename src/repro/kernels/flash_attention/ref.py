"""Pure-jnp oracle for the flash attention kernel (GQA, causal, windowed)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, window: Optional[int] = None, causal: bool = True):
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D).  fp32 softmax."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    qg = q.reshape(b, kv, g, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
