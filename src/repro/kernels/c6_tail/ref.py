"""Pure-jnp oracle for the fused C6 repair tail (one demotion round's gains).

Every C6 bandwidth-repair round evaluates, for each task, the bandwidth draw
of its current (r, p) config plus the two candidate demotions (drop fps,
drop resolution), their pointwise accuracies, and the reclaimable-bandwidth
gain.  Historically that was two ``take_along_axis`` gathers on the hoisted
route-indexed (M, N·Z) bandwidth panel plus two ``accuracy_at`` formula
evaluations dispatched separately; this ref fuses the whole tail into one
traced function (the CPU hot path), and the Pallas kernel keeps the panel
tile resident and one-hot-folds the gathers on TPU.

Bit-parity contract: the same gathers of the same panel and the same
``_accuracy_formula`` elementwise ops in the same order as the historical
``enforce_bandwidth`` round body — decisions and bandwidth histories are
bit-identical (tests/test_router.py locks this against the table-building
golden; tests/test_kernels.py locks kernel-vs-ref).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG  # shared infeasibility sentinel


def c6_tail_ref(bw_panel, r, p, v, route, z, acc_thr, rn, pn, n_fps: int):
    """One repair round's demotion candidates for a task batch.

    bw_panel: (M, N·Z) route-indexed bandwidth panel (flat r·Z + p minor);
    r/p/v/route: (M,) current decision indices; z: (M,) difficulty;
    acc_thr: (M,) accuracy floor (A^q + robust margin); rn: (N,) / pn: (Z,)
    normalized accuracy-formula coordinates.

    Returns ``(bw, gain, can_p)``: the current per-task draw, the reclaimed
    bandwidth of each task's preferred feasible demotion (-BIG when neither
    demotion stays feasible), and whether that demotion is the fps drop.
    """
    take_bw = lambda ri, pi: jnp.take_along_axis(
        bw_panel, (ri * n_fps + pi)[:, None], axis=1)[:, 0]
    bw = take_bw(r, p)
    # candidate demotion: prefer dropping fps, then resolution
    p_dn = jnp.maximum(p - 1, 0)
    r_dn = jnp.maximum(r - 1, 0)
    vf = v.astype(jnp.float32)
    tf = route.astype(jnp.float32)
    f_pdn = _accuracy_formula(z, rn[r], pn[p_dn], vf, tf)
    f_rdn = _accuracy_formula(z, rn[r_dn], pn[p], vf, tf)
    can_p = (p > 0) & (f_pdn >= acc_thr)
    can_r = (r > 0) & (f_rdn >= acc_thr)
    gain_p = bw - take_bw(r, p_dn)
    gain_r = bw - take_bw(r_dn, p)
    gain = jnp.where(can_p, gain_p, jnp.where(can_r, gain_r, -BIG))
    return bw, gain, can_p
