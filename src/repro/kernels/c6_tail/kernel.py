"""Pallas TPU kernel for the fused C6 repair tail.

One pass per M-tile computes a repair round's per-task quantities — current
bandwidth draw, both candidate-demotion accuracies, and the reclaimable
gain — with the route-indexed (bm, N·Z) bandwidth panel tile and the (N,) /
(Z,) coordinate vectors VMEM-resident.  The dynamic row gathers of the jnp
ref become one-hot max selects (exact: masked-out entries contribute -BIG),
and the accuracy formula is evaluated pointwise on the selected coordinates,
so the kernel is bit-identical to ``c6_tail_ref`` (tests/test_kernels.py).

The global demotion choice (descending-gain argsort + cumulative-gain
prefix) is a cross-task reduction and stays outside the kernel in
``enforce_bandwidth``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG


def _tail_kernel(panel_ref, r_ref, p_ref, v_ref, route_ref, z_ref, thr_ref,
                 rn_ref, pn_ref, bw_ref, gain_ref, canp_ref, *, n_fps):
    bm, nz_flat = panel_ref.shape
    n = rn_ref.shape[0]
    z_n = pn_ref.shape[0]
    panel = panel_ref[...]
    r = r_ref[...]
    p = p_ref[...]
    z = z_ref[...]
    thr = thr_ref[...]
    flat_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, nz_flat), 1)
    n_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    z_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, z_n), 1)

    def take_bw(ri, pi):
        oh = flat_idx == (ri * n_fps + pi)[:, None]
        return jnp.where(oh, panel, -BIG).max(axis=1)

    def sel_n(vec, idx):
        return jnp.where(n_idx == idx[:, None], vec[None, :], -BIG).max(axis=1)

    def sel_z(vec, idx):
        return jnp.where(z_idx == idx[:, None], vec[None, :], -BIG).max(axis=1)

    bw = take_bw(r, p)
    p_dn = jnp.maximum(p - 1, 0)
    r_dn = jnp.maximum(r - 1, 0)
    vf = v_ref[...].astype(jnp.float32)
    tf = route_ref[...].astype(jnp.float32)
    f_pdn = _accuracy_formula(z, sel_n(rn_ref[...], r), sel_z(pn_ref[...], p_dn), vf, tf)
    f_rdn = _accuracy_formula(z, sel_n(rn_ref[...], r_dn), sel_z(pn_ref[...], p), vf, tf)
    can_p = (p > 0) & (f_pdn >= thr)
    can_r = (r > 0) & (f_rdn >= thr)
    gain_p = bw - take_bw(r, p_dn)
    gain_r = bw - take_bw(r_dn, p)
    gain = jnp.where(can_p, gain_p, jnp.where(can_r, gain_r, -BIG))

    bw_ref[...] = bw
    gain_ref[...] = gain
    canp_ref[...] = can_p.astype(jnp.int32)


def c6_tail(bw_panel, r, p, v, route, z, acc_thr, rn, pn, *, n_fps: int,
            block_m: int = 256, interpret: bool = False):
    """bw_panel: (M, N·Z); r/p/v/route: (M,) int32; z/acc_thr: (M,);
    rn: (N,) / pn: (Z,) -> (bw (M,), gain (M,), can_p (M,) int32).
    M must divide block_m (the ops wrapper pads)."""
    m, nz_flat = bw_panel.shape
    n = rn.shape[0]
    z_n = pn.shape[0]
    bm = min(block_m, m)
    assert m % bm == 0 and nz_flat == n * n_fps
    grid = (m // bm,)

    lane = lambda: pl.BlockSpec((bm,), lambda mi: (mi,))
    return pl.pallas_call(
        partial(_tail_kernel, n_fps=n_fps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nz_flat), lambda mi: (mi, 0)),
            lane(), lane(), lane(), lane(), lane(), lane(),
            pl.BlockSpec((n,), lambda mi: (0,)),
            pl.BlockSpec((z_n,), lambda mi: (0,)),
        ],
        out_specs=[lane(), lane(), lane()],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(bw_panel, r, p, v, route, z, acc_thr, rn, pn)
