"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.c6_tail.kernel import c6_tail as _pallas
from repro.kernels.c6_tail.ref import c6_tail_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_fps", "block_m", "force"))
def c6_tail(bw_panel, r, p, v, route, z, acc_thr, rn, pn, *, n_fps: int,
            block_m: int = 256, force: str = "auto"):
    """Fused C6 repair tail -> (bw, gain, can_p) for one demotion round.

    bw_panel: (M, N·Z) route-indexed bandwidth panel; r/p/v/route: (M,)
    decision indices; z: (M,) difficulty; acc_thr: (M,) accuracy floor
    (A^q + margin); rn/pn: (N,)/(Z,) normalized coordinates.

    ``force``: "auto" picks Pallas on TPU and the jnp ref elsewhere;
    "pallas"/"ref" override (Pallas runs in interpret mode off-TPU).  M is
    padded up to the kernel block; padded lanes read panel row 0 with r=p=0
    (no demotion possible, gain -BIG) and are sliced off.
    """
    if force == "ref" or (force == "auto" and not _on_tpu()):
        bw, gain, can_p = _ref(bw_panel, r, p, v, route, z, acc_thr, rn, pn,
                               n_fps)
        return bw, gain, can_p
    m = bw_panel.shape[0]
    bm = min(block_m, m)
    pad_m = (-m) % bm
    if pad_m:
        bw_panel = jnp.pad(bw_panel, ((0, pad_m), (0, 0)))
        r = jnp.pad(r, (0, pad_m))
        p = jnp.pad(p, (0, pad_m))
        v = jnp.pad(v, (0, pad_m))
        route = jnp.pad(route, (0, pad_m))
        z = jnp.pad(z, (0, pad_m))
        acc_thr = jnp.pad(acc_thr, (0, pad_m))
    bw, gain, can_p = _pallas(
        bw_panel.astype(jnp.float32), r.astype(jnp.int32), p.astype(jnp.int32),
        v.astype(jnp.int32), route.astype(jnp.int32), z.astype(jnp.float32),
        acc_thr.astype(jnp.float32), rn.astype(jnp.float32),
        pn.astype(jnp.float32), n_fps=n_fps, block_m=bm,
        interpret=not _on_tpu(),
    )
    return bw[:m], gain[:m], can_p[:m] > 0
