"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd dispatch wrapper), and ref.py (pure-jnp oracle).
Validated in interpret mode on CPU; compiled natively on TPU.

  flash_attention  — GQA causal/windowed prefill+train attention
  decode_attention — single-token KV-cache attention (serving hot loop)
  mamba_scan       — blocked Mamba-1 selective scan (falcon-mamba)
  rglru            — blocked RG-LRU recurrence (recurrentgemma)
  temporal_gate    — fused R2E-VID gating cell (paper Eq. 5-6)
  ccg_master       — masked CCG master step (paper Alg. 2 MP1, unrolled solver)
  ccg_encode       — fused per-task CCG encoding (accuracy -> feasibility
                     bitmask -> recourse slab, table-free routing hot path)
  ccg_solve        — fully fused CCG solver: encode -> master/SP alternation
                     -> η updates across all iterations in one kernel call
  c6_tail          — fused C6 bandwidth-repair tail (per-round demotion
                     candidates: draw, accuracies, reclaimable gain)

See README.md in this directory for the kernel-family map and the
ref-vs-Pallas dispatch rules (``force=`` pins).
"""
from repro.kernels.c6_tail.ops import c6_tail  # noqa: F401
from repro.kernels.ccg_encode.ops import ccg_encode  # noqa: F401
from repro.kernels.ccg_master.ops import ccg_master  # noqa: F401
from repro.kernels.ccg_solve.ops import ccg_solve  # noqa: F401
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.mamba_scan.ops import selective_scan  # noqa: F401
from repro.kernels.rglru.ops import rglru_scan  # noqa: F401
from repro.kernels.temporal_gate.ops import gate_cell  # noqa: F401
