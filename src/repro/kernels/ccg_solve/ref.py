"""Pure-jnp oracle for the fully fused CCG solve (paper Alg. 2, end to end).

PR 4 fused the *encode* (accuracy formula -> feasibility bitmask -> recourse
slab); the solver still dispatched one master + SP update per unrolled step
from ``repro.core.robust.solve_ccg``.  This ref IS the table-free CPU hot
path for the whole alternation: encode, master argmin, exact SP pole
selection, and the running η-max all live in one traced function, so XLA
fuses the entire solve into a handful of (M, F) passes with no (M, P, F)
recourse slab materialized at all — η is a running (M, F) max and every
recourse value is recomputed as a K-fold masked min over the (F, K) cost
table (bit-identical to gathering the (P, F, 2^K) lookup: entry ``[p, f, c]``
of that lookup *is* ``min_{k∈c} b2[f, k]·(1+u_p,k)``, float min is exact, and
identical-operand multiplies are bitwise deterministic).

Decisions, bounds, and iteration counts are bit-identical to
``solve_ccg`` / ``solve_ccg_while`` (the retained oracles — covered by
tests/test_kernels.py and tests/test_robust.py).  Three exactness-preserving
trims keep the chain short:

  * argmin/argmax are computed as min/max + first-index-achieving-it (a
    masked iota min), which is bit-identical to ``jnp.argmin``/``argmax``
    tie-breaking and avoids the second gather XLA lowers for
    ``take_along_axis``;
  * the ``has_scen`` carry is dropped: cold lanes start η at 0 (recourse
    values are ≥ 0, so the first real scenario's max overwrites it) and the
    warm seed writes its pole's recourse row directly;
  * after ``unroll_head`` full-batch steps the batch-level early-exit
    ``while_loop`` takes over on a *compacted* batch: the live lanes are
    stable-partition-gathered into the narrowest of {M/4, M/2} that holds
    them (per-lane math is lane-independent, so compaction cannot change any
    lane's trajectory), and when more than half the lanes are still live —
    the cold megabatch case — one more live-gated full-batch step runs first
    to push the count under the threshold before re-picking the width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG  # shared infeasibility sentinel


def ccg_solve_ref(z, aq, rn_flat, pn_flat, tier_flat, b2_flat, u_all, c1,
                  warm_y, margin, num_versions: int, max_iters: int,
                  theta: float, unroll_head: int = 2, y_ok=None):
    """Fused CCG solve for a task batch.

    z/aq: (M,) difficulty and accuracy requirement; rn/pn/tier_flat: (F,)
    normalized option coordinates; b2_flat: (F, K) second-stage costs;
    u_all: (P, K) pole deviations (poles · ũ); c1: (F,) first-stage costs;
    warm_y: (M,) int32 flat warm starts (-1 = cold); margin: robust accuracy
    margin; theta: CCG gap tolerance; y_ok: optional (F,) availability mask —
    options at ``y_ok <= 0`` are outaged: clamped to -BIG accuracy so they
    drop out of feasibility AND the all-infeasible fallback argmax (the
    fallback always lands on a surviving server).

    Returns ``(y_f, v_star, o_up, o_down, iters, infeasible)`` — the
    converged first-stage flat index and second-stage version (both with the
    all-infeasible max-accuracy fallback already applied), the objective
    bounds, per-lane iteration counts, and the infeasibility flags.
    """
    m = z.shape[0]
    F = rn_flat.shape[0]
    K = num_versions
    P = u_all.shape[0]
    opu = 1.0 + u_all                                     # (P, K)
    kbit = jnp.arange(K, dtype=jnp.int32)
    IOTA_F = jnp.arange(F, dtype=jnp.int32)[None]
    IOTA_P = jnp.arange(P, dtype=jnp.int32)[None]

    # ---- encode: feasibility bitmask + flat accuracy argmax, K-folded ----
    z2 = jnp.asarray(z)[:, None]
    thr = (jnp.asarray(aq) + margin)[:, None]
    rn, pn, tf = rn_flat[None, :], pn_flat[None, :], tier_flat[None, :]
    okm = None if y_ok is None else (jnp.asarray(y_ok) > 0)[None, :]
    code = jnp.zeros((m, F), jnp.int8)
    bv = bk = None
    for k in range(K):
        f_k = _accuracy_formula(z2, rn, pn, jnp.float32(k), tf)   # (M, F)
        if okm is not None:
            f_k = jnp.where(okm, f_k, -BIG)
        code = code | jnp.where(f_k >= thr, jnp.int8(1 << k), jnp.int8(0))
        # running argmax over the flat (F·K) space (k minor): track the best
        # value and its k per option, resolve the F argmax once at the end
        if k == 0:
            bv, bk = f_k, jnp.zeros((m, F), jnp.int8)
        else:
            up = f_k > bv
            bv = jnp.where(up, f_k, bv)
            bk = jnp.where(up, jnp.int8(k), bk)
    bmax = bv.max(axis=1)
    by = jnp.where(bv == bmax[:, None], IOTA_F, F).min(axis=1)
    best = by * K + jnp.take_along_axis(bk, by[:, None], axis=1)[:, 0].astype(jnp.int32)
    fs_ok = code > 0                                      # (M, F)

    def sp_at(code, y, mm):
        """(mm, P) recourse of option y at every pole — K-fold, no table."""
        b2y = b2_flat[y]                                  # (mm, K) row gather
        cy = jnp.take_along_axis(code, y[:, None], axis=1)[:, 0]
        sp = jnp.full((mm, P), BIG, jnp.float32)
        for k in range(K):
            term = b2y[:, k][:, None] * opu[None, :, k]   # (mm, P)
            bit = ((cy >> k) & 1) > 0
            sp = jnp.where(bit[:, None], jnp.minimum(sp, term), sp)
        return sp

    def rec_at(code, pole, mm):
        """(mm, F) recourse row of each lane's pole — K-fold, no table."""
        uw = opu[pole]                                    # (mm, K) row gather
        rec = jnp.full((mm, F), BIG, jnp.float32)
        for k in range(K):
            term = b2_flat[None, :, k] * uw[:, k][:, None]
            bit = ((code >> k) & 1) > 0
            rec = jnp.where(bit, jnp.minimum(rec, term), rec)
        return rec

    def step(code, fs_ok, carry):
        """One masked master/adversary alternation for a (sub-)batch."""
        mm = code.shape[0]
        stepv, eta_run, o_up, o_down, y_best, iters, done = carry
        live = ~done
        # MP1: η is the running max of generated scenario rows
        obj = jnp.where(fs_ok, c1[None] + eta_run, BIG)
        od_new = obj.min(axis=1)
        y_star = jnp.where(obj == od_new[:, None], IOTA_F, F).min(axis=1)
        # SP: exact worst-case pole for y_star (Eq. 10 pole optimality)
        sp_vals = sp_at(code, y_star, mm)
        q = sp_vals.max(axis=1)
        worst_pole = jnp.where(sp_vals == q[:, None], IOTA_P, P).min(axis=1)
        cand = c1[y_star] + q
        up_new = jnp.minimum(o_up, cand)
        # the returned decision is the INCUMBENT achieving O_up, not the
        # last master argmin (a θ-tied y_star may be worse)
        y_best = jnp.where(live & (cand < o_up), y_star, y_best)
        o_down = jnp.where(live, od_new, o_down)
        o_up = jnp.where(live, up_new, o_up)
        # done lanes' η may keep moving — every read of it is live-gated
        eta_run = jnp.maximum(eta_run, rec_at(code, worst_pole, mm))
        iters = iters + live.astype(jnp.int32)
        done = jnp.where(live, (up_new - od_new) <= theta, done)
        return (stepv + 1, eta_run, o_up, o_down, y_best, iters, done)

    # ---- warm start: seed the scenario set with the warm y's worst pole ----
    if warm_y is None:
        warm_y = -jnp.ones((m,), jnp.int32)
    wyc = jnp.maximum(warm_y, 0)
    use_warm = (warm_y >= 0) & jnp.take_along_axis(fs_ok, wyc[:, None], axis=1)[:, 0]
    rec_wy = sp_at(code, wyc, m)                          # (M, P)
    q_w = rec_wy.max(axis=1)
    warm_pole = jnp.where(rec_wy == q_w[:, None], IOTA_P, P).min(axis=1)
    o_up = jnp.where(use_warm, c1[wyc] + q_w, BIG)
    eta_run = jnp.where(use_warm[:, None], rec_at(code, warm_pole, m), 0.0)

    n_steps = min(max_iters, P + 1)
    carry = (jnp.int32(0), eta_run, o_up, jnp.full((m,), -BIG, jnp.float32),
             wyc, jnp.zeros((m,), jnp.int32), jnp.zeros((m,), bool))

    # head unroll only pays at batch sizes where the per-step fixed cost of
    # the while_loop carry matters less than wasted full-batch steps
    head = min(unroll_head, n_steps) if m >= 256 else 0
    for _ in range(head):
        carry = step(code, fs_ok, carry)

    if head >= n_steps:
        _, _, o_up, o_down, y_best, iters, done = carry
    elif head == 0:
        out = jax.lax.while_loop(
            lambda c: (c[0] < n_steps) & ~c[-1].all(),
            lambda c: step(code, fs_ok, c), carry)
        _, _, o_up, o_down, y_best, iters, done = out
    else:
        mh, mq = m // 2, max(m // 4, 1)
        stepv, eta_run, o_up, o_down, y_best, iters, done = carry

        def tail_full(stepv, op):
            eta_run, o_up, o_down, y_best, iters, done = op
            out = jax.lax.while_loop(
                lambda c: (c[0] < n_steps) & ~c[-1].all(),
                lambda c: step(code, fs_ok, c),
                (stepv, eta_run, o_up, o_down, y_best, iters, done))
            return out[2], out[3], out[4], out[5], out[6]

        def tail_compact(mc, stepv, op):
            # stable-partition the live lanes into an mc-size batch; lane m
            # is the out-of-bounds sentinel for dead slots (drop semantics on
            # both the gather setup and the scatter-back)
            eta_run, o_up, o_down, y_best, iters, done = op
            live = ~done
            nlive = live.sum()
            pos = jnp.cumsum(live) - 1
            iota_m = jnp.arange(m, dtype=jnp.int32)
            lane = jnp.full((mc,), m, jnp.int32).at[
                jnp.where(live, pos, m)].set(iota_m, mode="drop")
            slot_live = jnp.arange(mc) < nlive
            lane_c = jnp.minimum(lane, m - 1)      # clamp for safe gathers
            code_c = code[lane_c]
            fs_ok_c = code_c > 0
            carry_c = (stepv, eta_run[lane_c],
                       o_up[lane_c], o_down[lane_c], y_best[lane_c],
                       iters[lane_c], ~slot_live | done[lane_c])
            out = jax.lax.while_loop(
                lambda c: (c[0] < n_steps) & ~c[-1].all(),
                lambda c: step(code_c, fs_ok_c, c), carry_c)
            _, _, o_up_c, o_down_c, y_best_c, iters_c, done_c = out
            return (o_up.at[lane].set(o_up_c, mode="drop"),
                    o_down.at[lane].set(o_down_c, mode="drop"),
                    y_best.at[lane].set(y_best_c, mode="drop"),
                    iters.at[lane].set(iters_c, mode="drop"),
                    done.at[lane].set(done_c, mode="drop"))

        def pick_width(stepv, op):
            # narrowest compaction width holding every live lane (per-lane
            # math is lane-independent, so width never changes trajectories)
            live_n = (~op[-1]).sum()
            return jax.lax.cond(
                live_n <= mq,
                lambda o: tail_compact(mq, stepv, o),
                lambda o: jax.lax.cond(
                    live_n <= mh,
                    lambda oo: tail_compact(mh, stepv, oo),
                    lambda oo: tail_full(stepv, oo),
                    o),
                op)

        def retry(op):
            # more than half the lanes still live: one more full-batch step
            # typically drops the cold megabatch under the compaction
            # threshold (the step is live-gated, so running it here is
            # bit-identical to the full tail running it)
            eta_run, o_up, o_down, y_best, iters, done = op
            c2 = step(code, fs_ok,
                      (stepv, eta_run, o_up, o_down, y_best, iters, done))
            return pick_width(c2[0], c2[1:])

        operand = (eta_run, o_up, o_down, y_best, iters, done)
        o_up, o_down, y_best, iters, done = jax.lax.cond(
            (~done).sum() <= mh,
            lambda op: pick_width(stepv, op),
            retry, operand)

    # ---- epilogue: final worst pole, v*, all-infeasible fallback ----
    sp_vals = sp_at(code, y_best, m)
    qf = sp_vals.max(axis=1)
    worst = jnp.where(sp_vals == qf[:, None], IOTA_P, P).min(axis=1)
    u = u_all[worst]                                      # (M, K)
    code_y = jnp.take_along_axis(code, y_best[:, None], axis=1)[:, 0]
    feas_y = ((code_y[:, None] >> kbit[None]) & 1) > 0
    vals = jnp.where(feas_y, b2_flat[y_best] * (1.0 + u), BIG)
    vmin = vals.min(axis=1)
    v_star = jnp.where(vals == vmin[:, None], kbit[None], K).min(axis=1)
    none_ok = ~fs_ok.any(axis=1)
    y_f = jnp.where(none_ok, best // K, y_best)
    v_star = jnp.where(none_ok, best % K, v_star)
    return y_f, v_star, o_up, o_down, iters, none_ok
