"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ccg_solve.kernel import ccg_solve as _pallas
from repro.kernels.ccg_solve.ref import ccg_solve_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("margin", "num_versions", "max_iters",
                                   "theta", "block_m", "force"))
def ccg_solve(z, aq, rn_flat, pn_flat, tier_flat, b2_flat, u_all, c1_flat,
              warm_y, *, margin: float, num_versions: int, max_iters: int = 8,
              theta: float = 1e-4, block_m: int = 128, force: str = "auto",
              y_ok=None):
    """Fully fused CCG solve -> (y_f, v_star, o_up, o_down, iters, infeasible).

    z/aq: (M,) task difficulty and accuracy requirement; rn/pn/tier_flat:
    (F,) normalized option coordinates; b2_flat: (F, K) second-stage costs;
    u_all: (P, K) pole deviations; c1_flat: (F,) first-stage costs; warm_y:
    (M,) int32 flat warm starts (-1 = cold); y_ok: optional (F,) availability
    mask — options at ``y_ok <= 0`` become infeasible and lose the fallback
    argmax (scenario outages).  Runs encode -> master argmin ->
    SP pole selection -> η update across all min(max_iters, P+1) CCG steps in
    one pass — no per-step dispatch, no (M, P, F) recourse slab.

    ``force``: "auto" picks Pallas on TPU and the jnp ref elsewhere;
    "pallas"/"ref" override (Pallas runs in interpret mode off-TPU).  M is
    padded up to the kernel block; padded lanes are cold, all-infeasible-safe
    dummies sliced off before returning.
    """
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return _ref(z, aq, rn_flat, pn_flat, tier_flat, b2_flat, u_all,
                    c1_flat, warm_y, margin, num_versions, max_iters, theta,
                    y_ok=y_ok)
    m = z.shape[0]
    bm = min(block_m, m)
    pad_m = (-m) % bm
    if pad_m:
        z = jnp.pad(z, (0, pad_m))
        aq = jnp.pad(aq, (0, pad_m))
        warm_y = jnp.pad(warm_y, (0, pad_m), constant_values=-1)
    ok = (jnp.ones_like(rn_flat) if y_ok is None else jnp.asarray(y_ok))
    y_f, v_star, o_up, o_down, iters, infeas = _pallas(
        z.astype(jnp.float32), aq.astype(jnp.float32),
        warm_y.astype(jnp.int32),
        rn_flat.astype(jnp.float32), pn_flat.astype(jnp.float32),
        tier_flat.astype(jnp.float32), ok.astype(jnp.float32),
        jnp.moveaxis(b2_flat, -1, 0).astype(jnp.float32),    # (K, F)
        u_all.astype(jnp.float32), c1_flat.astype(jnp.float32),
        margin=margin, num_versions=num_versions, max_iters=max_iters,
        theta=theta, block_m=bm, interpret=not _on_tpu(),
    )
    return (y_f[:m], v_star[:m], o_up[:m], o_down[:m], iters[:m],
            infeas[:m] > 0)
