"""Pallas TPU kernel for the fully fused CCG solve (paper Alg. 2).

One pass per M-tile runs the *entire* column-and-constraint alternation:
encode (accuracy formula -> feasible-version bitmask), then
min(max_iters, P+1) unrolled master/adversary steps — feasibility-masked
argmin over the F flat options, exact SP pole selection, running (bm, F)
η-max — and the final-recourse epilogue, all without leaving VMEM.  The
(F, K) cost table, (P, K) pole deviations, and (F,) coordinate/cost vectors
are broadcast blocks resident across the whole M sweep; the per-lane state
(η slab, bounds, incumbent, done flags) lives in registers/VMEM for all
steps, so the solve makes zero HBM round-trips between CCG iterations.

Bit-parity contract with ``ccg_solve_ref`` (and hence ``solve_ccg`` /
``solve_ccg_while``): every argmin/argmax is min/max + masked-iota-min
(first index achieving the extremum — identical tie-breaking); row gathers
are one-hot max/sum selects (exact: the masked-out lanes contribute -BIG to
a max or 0 to an integer sum); recourse values are K-fold masked mins over
the same products the (P, F, 2^K) lookup was built from, and float min is
exact.  Done lanes are frozen by live-gating every state write, so the full
unroll (no early exit inside a kernel) is bit-identical to the ref's
early-exiting while_loop.  Covered by tests/test_kernels.py in interpret
mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG

_INT_MAX = jnp.iinfo(jnp.int32).max


def _solve_kernel(z_ref, aq_ref, wy_ref, rn_ref, pn_ref, tf_ref, ok_ref,
                  b2k_ref, u_ref, c1_ref, y_ref, v_ref, oup_ref, odn_ref,
                  it_ref, inf_ref, *, margin, num_versions, n_steps, theta):
    bm = z_ref.shape[0]
    f = rn_ref.shape[0]
    k_n = num_versions
    p_n = u_ref.shape[0]

    z = z_ref[...][:, None]                               # (bm, 1)
    thr = aq_ref[...][:, None] + margin
    rn = rn_ref[...][None, :]                             # (1, F)
    pn = pn_ref[...][None, :]
    tf = tf_ref[...][None, :]
    ok = ok_ref[...][None, :] > 0                         # (1, F) availability
    c1 = c1_ref[...]                                      # (F,)
    opu = 1.0 + u_ref[...]                                # (P, K)
    fidx = jax.lax.broadcasted_iota(jnp.int32, (bm, f), 1)
    pidx = jax.lax.broadcasted_iota(jnp.int32, (bm, p_n), 1)

    def sel_f(vec, idx):
        """vec[idx] for a (F,) vec and (bm,) idx — one-hot max select."""
        return jnp.where(fidx == idx[:, None], vec[None, :], -BIG).max(axis=1)

    def sel_p(vec, idx):
        """vec[idx] for a (P,) vec and (bm,) idx — one-hot max select."""
        return jnp.where(pidx == idx[:, None], vec[None, :], -BIG).max(axis=1)

    # ---- encode: feasibility bitmask + flat accuracy argmax ----
    code = jnp.zeros((bm, f), jnp.int32)
    bv = jnp.zeros((bm, f), jnp.float32)
    bk = jnp.zeros((bm, f), jnp.int32)
    for k in range(k_n):
        f_k = _accuracy_formula(z, rn, pn, jnp.float32(k), tf)    # (bm, F)
        f_k = jnp.where(ok, f_k, -BIG)
        code = code | jnp.where(f_k >= thr, jnp.int32(1 << k), 0)
        if k == 0:
            bv = f_k
        else:
            up = f_k > bv
            bv = jnp.where(up, f_k, bv)
            bk = jnp.where(up, k, bk)
    bmax = bv.max(axis=1)
    by = jnp.where(bv == bmax[:, None], fidx, _INT_MAX).min(axis=1)
    bk_y = jnp.where(fidx == by[:, None], bk, 0).sum(axis=1)
    best = by * k_n + bk_y
    fs_ok = code > 0

    def sp_at(y):
        """(bm, P) recourse of option y at every pole — K-fold select."""
        oh = fidx == y[:, None]
        cy = jnp.where(oh, code, 0).sum(axis=1)           # (bm,)
        sp = jnp.full((bm, p_n), BIG, jnp.float32)
        for k in range(k_n):
            b2y_k = jnp.where(oh, b2k_ref[k][None, :], -BIG).max(axis=1)
            term = b2y_k[:, None] * opu[None, :, k]       # (bm, P)
            bit = ((cy >> k) & 1) > 0
            sp = jnp.where(bit[:, None], jnp.minimum(sp, term), sp)
        return sp, cy

    def rec_at(pole):
        """(bm, F) recourse row of each lane's pole — K-fold select."""
        rec = jnp.full((bm, f), BIG, jnp.float32)
        for k in range(k_n):
            uw_k = sel_p(opu[:, k], pole)                 # (bm,)
            term = b2k_ref[k][None, :] * uw_k[:, None]    # (bm, F)
            bit = ((code >> k) & 1) > 0
            rec = jnp.where(bit, jnp.minimum(rec, term), rec)
        return rec

    # ---- warm start seeding ----
    wy = wy_ref[...]
    wyc = jnp.maximum(wy, 0)
    fs_wy = jnp.where(fidx == wyc[:, None], fs_ok, False).any(axis=1)
    use_warm = (wy >= 0) & fs_wy
    rec_wy, _ = sp_at(wyc)
    q_w = rec_wy.max(axis=1)
    warm_pole = jnp.where(rec_wy == q_w[:, None], pidx, _INT_MAX).min(axis=1)
    o_up = jnp.where(use_warm, sel_f(c1, wyc) + q_w, BIG)
    eta_run = jnp.where(use_warm[:, None], rec_at(warm_pole), 0.0)

    o_down = jnp.full((bm,), -BIG, jnp.float32)
    y_best = wyc
    iters = jnp.zeros((bm,), jnp.int32)
    done = jnp.zeros((bm,), bool)

    # ---- unrolled CCG alternation (live-gated, done lanes frozen) ----
    for _ in range(n_steps):
        live = ~done
        obj = jnp.where(fs_ok, c1[None, :] + eta_run, BIG)
        od_new = obj.min(axis=1)
        y_star = jnp.where(obj == od_new[:, None], fidx, _INT_MAX).min(axis=1)
        sp_vals, _ = sp_at(y_star)
        q = sp_vals.max(axis=1)
        worst_pole = jnp.where(sp_vals == q[:, None], pidx, _INT_MAX).min(axis=1)
        cand = sel_f(c1, y_star) + q
        up_new = jnp.minimum(o_up, cand)
        y_best = jnp.where(live & (cand < o_up), y_star, y_best)
        o_down = jnp.where(live, od_new, o_down)
        o_up = jnp.where(live, up_new, o_up)
        eta_run = jnp.maximum(eta_run, rec_at(worst_pole))
        iters = iters + live.astype(jnp.int32)
        done = jnp.where(live, (up_new - od_new) <= theta, done)

    # ---- epilogue: final worst pole, v*, all-infeasible fallback ----
    sp_vals, code_y = sp_at(y_best)
    qf = sp_vals.max(axis=1)
    worst = jnp.where(sp_vals == qf[:, None], pidx, _INT_MAX).min(axis=1)
    vals = jnp.full((bm, k_n), BIG, jnp.float32)
    oh_y = fidx == y_best[:, None]
    for k in range(k_n):
        b2y_k = jnp.where(oh_y, b2k_ref[k][None, :], -BIG).max(axis=1)
        u_k = sel_p(u_ref[...][:, k], worst)
        feas_k = ((code_y >> k) & 1) > 0
        vals = vals.at[:, k].set(
            jnp.where(feas_k, b2y_k * (1.0 + u_k), BIG))
    vmin = vals.min(axis=1)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (bm, k_n), 1)
    v_star = jnp.where(vals == vmin[:, None], kidx, _INT_MAX).min(axis=1)
    none_ok = ~fs_ok.any(axis=1)
    y_f = jnp.where(none_ok, best // k_n, y_best)
    v_star = jnp.where(none_ok, best % k_n, v_star)

    y_ref[...] = y_f
    v_ref[...] = v_star
    oup_ref[...] = o_up
    odn_ref[...] = o_down
    it_ref[...] = iters
    inf_ref[...] = none_ok.astype(jnp.int32)


def ccg_solve(z, aq, warm_y, rn_flat, pn_flat, tier_flat, y_ok, b2k, u_all,
              c1_flat, *, margin: float, num_versions: int, max_iters: int = 8,
              theta: float = 1e-4, block_m: int = 128,
              interpret: bool = False):
    """z/aq: (M,); warm_y: (M,) int32; rn/pn/tier_flat, c1_flat, y_ok: (F,)
    — y_ok is the availability mask (all-ones when no outage);
    b2k: (K, F) transposed second-stage costs; u_all: (P, K) pole deviations
    -> (y_f, v_star, o_up, o_down, iters, infeasible(int32)), all (M,).
    M must divide block_m (the ops wrapper pads)."""
    m = z.shape[0]
    f = rn_flat.shape[0]
    k, p = num_versions, u_all.shape[0]
    bm = min(block_m, m)
    assert m % bm == 0 and b2k.shape == (k, f)
    n_steps = min(max_iters, p + 1)
    grid = (m // bm,)

    lane = lambda: pl.BlockSpec((bm,), lambda mi: (mi,))
    vec_f = lambda: pl.BlockSpec((f,), lambda mi: (0,))
    return pl.pallas_call(
        partial(_solve_kernel, margin=margin, num_versions=num_versions,
                n_steps=n_steps, theta=theta),
        grid=grid,
        in_specs=[
            lane(), lane(), lane(),
            vec_f(), vec_f(), vec_f(), vec_f(),
            pl.BlockSpec((k, f), lambda mi: (0, 0)),
            pl.BlockSpec((p, k), lambda mi: (0, 0)),
            vec_f(),
        ],
        out_specs=[lane() for _ in range(6)],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(z, aq, warm_y, rn_flat, pn_flat, tier_flat, y_ok, b2k, u_all, c1_flat)
