"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ccg_encode.kernel import ccg_encode as _pallas
from repro.kernels.ccg_encode.ref import ccg_encode_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("margin", "num_versions", "block_m", "force"))
def ccg_encode(z, aq, rn_flat, pn_flat, tier_flat, b2_scaled, rec_table, *,
               margin: float, num_versions: int, block_m: int = 128,
               force: str = "auto", y_ok=None):
    """Fused per-task CCG encoding -> (code, rec_all, best).

    z/aq: (M,) task difficulty and accuracy requirement; rn/pn/tier_flat:
    (F,) normalized option coordinates; b2_scaled: (P, F, K) pole-scaled
    second-stage costs (the kernel's VMEM-resident recourse source);
    rec_table: (P, F, 2^K) subset-min lookup (the ref's gather source — the
    two encode the same recourse values, see kernel.py).  ``y_ok`` is an
    optional (F,) availability mask: options at ``y_ok <= 0`` become
    infeasible and lose the fallback argmax (scenario outages).  Returns the
    (M, F) int32 feasible-version bitmask, the (M, P, F) recourse slab, and
    the (M,) flat accuracy argmax used by the all-infeasible fallback.

    ``force``: "auto" picks Pallas on TPU and the jnp ref elsewhere;
    "pallas"/"ref" override (Pallas runs in interpret mode off-TPU).  M is
    padded up to the kernel block, so any batch size works.
    """
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return _ref(z, aq, rn_flat, pn_flat, tier_flat, rec_table,
                    margin, num_versions, y_ok=y_ok)
    m = z.shape[0]
    bm = min(block_m, m)
    pad_m = (-m) % bm
    if pad_m:
        z = jnp.pad(z, (0, pad_m))
        aq = jnp.pad(aq, (0, pad_m))
    ok = (jnp.ones_like(rn_flat) if y_ok is None else jnp.asarray(y_ok))
    code, rec_all, best = _pallas(
        z.astype(jnp.float32),
        aq.astype(jnp.float32),
        rn_flat.astype(jnp.float32),
        pn_flat.astype(jnp.float32),
        tier_flat.astype(jnp.float32),
        ok.astype(jnp.float32),
        jnp.moveaxis(b2_scaled, -1, 0).astype(jnp.float32),   # (K, P, F)
        margin=margin, num_versions=num_versions, block_m=bm,
        interpret=not _on_tpu(),
    )
    return code[:m], rec_all[:m], best[:m]
