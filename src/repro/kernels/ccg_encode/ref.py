"""Pure-jnp oracle for the fused per-task CCG encoding (paper Alg. 2 inputs).

Every CCG sweep starts by encoding its task batch: evaluate the accuracy
surface f(z, y, k) over the flat first-stage options, threshold it into a
per-option feasible-version bitmask, and gather each (pole, option) recourse
value from the precomputed (P, F, 2^K) lookup.  The historical path built the
full (M, F, K) accuracy tensor first; this ref IS the table-free CPU hot
path: the K model versions are folded in one at a time, so the largest
accuracy intermediate is a single (M, F) slice and the only (M, ·, ·) tensor
materialized is the (M, P, F) recourse slab the solver needs anyway.

Outputs are bit-identical to the table route (same ``_accuracy_formula``
elementwise ops on gathers of the same normalized coordinate vectors, and
``min``/comparisons are exact in floats); the Pallas kernel must reproduce
this ref bit-for-bit (covered by tests/test_kernels.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG  # shared infeasibility sentinel


def ccg_encode_ref(z, aq, rn_flat, pn_flat, tier_flat, rec_table, margin,
                   num_versions: int, y_ok=None):
    """Fused task encoding for a CCG batch.

    z/aq: (M,) difficulty and accuracy requirement; rn/pn/tier_flat: (F,)
    normalized accuracy-formula coordinates of every flat option;
    rec_table: (P, F, 2^K) recourse lookup; margin: robust accuracy margin;
    y_ok: optional (F,) availability mask — options with ``y_ok <= 0`` are
    outaged: their accuracy is clamped to -BIG so they fail the feasibility
    threshold AND lose the fallback argmax, which keeps the all-infeasible
    fallback on a surviving server.

    Returns ``(code, rec_all, best)``:
      code    : (M, F) int32 feasible-version bitmask (bit k set iff version
                k clears A^q + margin at that option); ``code > 0`` is the
                first-stage feasibility mask
      rec_all : (M, P, F) per-pole recourse values (BIG where no version fits)
      best    : (M,) int32 argmax of accuracy over the flat (F·K) space
                (first-max ties, k minor) — the all-infeasible fallback config
    """
    z2 = jnp.asarray(z)[:, None]                         # (M, 1)
    thr = (jnp.asarray(aq) + margin)[:, None]            # (M, 1)
    rn = rn_flat[None, :]
    pn = pn_flat[None, :]
    tf = tier_flat[None, :]
    m = z2.shape[0]
    okm = None if y_ok is None else (jnp.asarray(y_ok) > 0)[None, :]

    code = jnp.zeros((m, rn_flat.shape[0]), jnp.int32)
    best_val = jnp.full((m,), -BIG, jnp.float32)
    best = jnp.zeros((m,), jnp.int32)
    for k in range(num_versions):
        f_k = _accuracy_formula(z2, rn, pn, jnp.float32(k), tf)  # (M, F)
        if okm is not None:
            f_k = jnp.where(okm, f_k, -BIG)
        code = code + jnp.where(f_k >= thr, jnp.int32(1 << k), 0)
        # running flat argmax (index y·K + k): per-k first max over F, then
        # strict->/tie-to-lower-index hand-off across k — matches
        # ``f_flat.reshape(M, -1).argmax(axis=1)`` exactly
        arg_k = jnp.argmax(f_k, axis=1)
        val_k = jnp.take_along_axis(f_k, arg_k[:, None], axis=1)[:, 0]
        flat_k = (arg_k * num_versions + k).astype(jnp.int32)
        better = (val_k > best_val) | ((val_k == best_val) & (flat_k < best))
        best = jnp.where(better, flat_k, best)
        best_val = jnp.where(better, val_k, best_val)

    rec_all = jnp.take_along_axis(
        rec_table[None], code[:, None, :, None], axis=-1
    )[..., 0]                                            # (M, P, F)
    return code, rec_all, best
