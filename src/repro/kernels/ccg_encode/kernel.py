"""Pallas TPU kernel for the fused per-task CCG encoding.

One pass per M-tile produces everything the unrolled robust solver needs
from a task batch: the accuracy surface is evaluated version-by-version
straight from the (F,) normalized option coordinates (VPU elementwise, no
(M, F, K) tensor), thresholded into the feasible-version bitmask, and the
(M, P, F) recourse slab is folded in place as a masked running min over the
pole-scaled second-stage costs.  The (K, P, F) scaled-cost slab — the
recourse lookup in its unexpanded form — stays VMEM-resident across the
whole M sweep (a few tens of KB vs the (M, P, F) HBM traffic XLA's
gather-based lowering makes per task).

The masked min-fold is value-identical to gathering the (P, F, 2^K) subset
lookup at the bitmask: entry ``[p, f, c]`` of that lookup *is*
``min_{k ∈ c} b2s[k, p, f]`` (BIG when c = ∅), and float min is exact, so
folding the same set elementwise reproduces the gather bit-for-bit.  Grid =
(n_m,): M is streamed in tiles, F (50 for the paper lattice) and the P ≤ 2^K
poles stay resident.  The running accuracy argmax hands off across versions
with strict-> / tie-to-lower-flat-index, matching ``jnp.argmax`` over the
(F·K) flat space (k minor).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import _accuracy_formula
from repro.kernels.ccg_master.ref import BIG

_INT_MAX = jnp.iinfo(jnp.int32).max


def _encode_kernel(z_ref, aq_ref, rn_ref, pn_ref, tf_ref, ok_ref, b2s_ref,
                   code_ref, rec_ref, best_ref, *, margin, num_versions):
    bm = z_ref.shape[0]
    f = rn_ref.shape[0]
    p = b2s_ref.shape[1]

    z = z_ref[...][:, None]                              # (bm, 1)
    thr = aq_ref[...][:, None] + margin
    rn = rn_ref[...][None, :]                            # (1, F)
    pn = pn_ref[...][None, :]
    tf = tf_ref[...][None, :]
    ok = ok_ref[...][None, :] > 0                        # (1, F) availability
    fidx = jax.lax.broadcasted_iota(jnp.int32, (bm, f), 1)

    code = jnp.zeros((bm, f), jnp.int32)
    rec = jnp.full((bm, p, f), BIG, jnp.float32)
    best_val = jnp.full((bm,), -BIG, jnp.float32)
    best = jnp.zeros((bm,), jnp.int32)
    for k in range(num_versions):
        f_k = _accuracy_formula(z, rn, pn, jnp.float32(k), tf)   # (bm, F)
        f_k = jnp.where(ok, f_k, -BIG)
        feas = f_k >= thr
        code = code + jnp.where(feas, jnp.int32(1 << k), 0)
        rec = jnp.where(feas[:, None, :],
                        jnp.minimum(rec, b2s_ref[k][None]), rec)
        # first-max argmax over F for this version, then strict hand-off
        row_max = f_k.max(axis=1)
        row_arg = jnp.where(f_k == row_max[:, None], fidx, _INT_MAX).min(axis=1)
        flat_k = row_arg * num_versions + k
        better = (row_max > best_val) | ((row_max == best_val) & (flat_k < best))
        best = jnp.where(better, flat_k, best)
        best_val = jnp.where(better, row_max, best_val)

    code_ref[...] = code
    rec_ref[...] = rec
    best_ref[...] = best


def ccg_encode(z, aq, rn_flat, pn_flat, tier_flat, y_ok, b2_scaled, *,
               margin: float, num_versions: int, block_m: int = 128,
               interpret: bool = False):
    """z/aq: (M,); rn/pn/tier_flat/y_ok: (F,) — y_ok is the availability
    mask (all-ones when no outage); b2_scaled: (K, P, F) pole-scaled
    second-stage costs -> (code (M, F) int32, rec_all (M, P, F) float32,
    best (M,) int32).  M must divide block_m (the ops wrapper pads)."""
    m = z.shape[0]
    f = rn_flat.shape[0]
    k, p, _ = b2_scaled.shape
    bm = min(block_m, m)
    assert m % bm == 0 and k == num_versions
    grid = (m // bm,)

    return pl.pallas_call(
        partial(_encode_kernel, margin=margin, num_versions=num_versions),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda mi: (mi,)),
            pl.BlockSpec((bm,), lambda mi: (mi,)),
            pl.BlockSpec((f,), lambda mi: (0,)),
            pl.BlockSpec((f,), lambda mi: (0,)),
            pl.BlockSpec((f,), lambda mi: (0,)),
            pl.BlockSpec((f,), lambda mi: (0,)),
            pl.BlockSpec((k, p, f), lambda mi: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, f), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, p, f), lambda mi: (mi, 0, 0)),
            pl.BlockSpec((bm,), lambda mi: (mi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, f), jnp.int32),
            jax.ShapeDtypeStruct((m, p, f), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(z, aq, rn_flat, pn_flat, tier_flat, y_ok, b2_scaled)
