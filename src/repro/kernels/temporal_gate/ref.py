"""Pure-jnp oracle for the fused temporal-gating cell (paper Eq. 5-6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gate_cell_ref(dx, h, vol, p):
    """One fused gating step for a batch of streams.

    dx: (B, d); h: (B, m); vol: (B,) volatility Var(Δx_{t-T:t}).
    p: dict with w_g,u_g,b_g,alpha,w_r,u_r,b_r,w_h,u_h,b_h,w_o,b_o.
    Returns (h_new (B, m), tau (B,), g_mean (B,)).

    The three dx-projections and the two h-projections are packed into one
    (d, 3m) and one (m, 2m) matmul each — four GEMMs per step instead of
    six.  Each output column's reduction is unchanged by the packing, so
    the gates are numerically identical to the historical separate-matmul
    form (tests lock the kernel/ref pair bit for bit).
    """
    m = h.shape[1]
    w_x = jnp.concatenate([p["w_g"], p["w_r"], p["w_h"]], axis=1)   # (d, 3m)
    u_gr = jnp.concatenate([p["u_g"], p["u_r"]], axis=1)            # (m, 2m)
    xw = dx @ w_x                                                   # (B, 3m)
    hu = h @ u_gr                                                   # (B, 2m)
    g = jax.nn.sigmoid(xw[:, :m] + hu[:, :m] + p["b_g"]
                       + (p["alpha"] * vol)[:, None])
    r = jax.nn.sigmoid(xw[:, m:2 * m] + hu[:, m:] + p["b_r"])
    cand = jnp.tanh(xw[:, 2 * m:] + (r * h) @ p["u_h"] + p["b_h"])
    h_new = (1.0 - g) * h + g * cand
    tau = jax.nn.sigmoid(h_new @ p["w_o"] + p["b_o"])[:, 0]
    return h_new, tau, g.mean(axis=-1)
