"""Pure-jnp oracle for the fused temporal-gating cell (paper Eq. 5-6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gate_cell_ref(dx, h, vol, p):
    """One fused gating step for a batch of streams.

    dx: (B, d); h: (B, m); vol: (B,) volatility Var(Δx_{t-T:t}).
    p: dict with w_g,u_g,b_g,alpha,w_r,u_r,b_r,w_h,u_h,b_h,w_o,b_o.
    Returns (h_new (B, m), tau (B,), g_mean (B,)).
    """
    g = jax.nn.sigmoid(dx @ p["w_g"] + h @ p["u_g"] + p["b_g"]
                       + (p["alpha"] * vol)[:, None])
    r = jax.nn.sigmoid(dx @ p["w_r"] + h @ p["u_r"] + p["b_r"])
    cand = jnp.tanh(dx @ p["w_h"] + (r * h) @ p["u_h"] + p["b_h"])
    h_new = (1.0 - g) * h + g * cand
    tau = jax.nn.sigmoid(h_new @ p["w_o"] + p["b_o"])[:, 0]
    return h_new, tau, g.mean(axis=-1)
