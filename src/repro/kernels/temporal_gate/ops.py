"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.temporal_gate.kernel import gate_cell as _pallas
from repro.kernels.temporal_gate.ref import gate_cell_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_b", "force"))
def gate_cell(dx, h, vol, p, *, block_b: int = 256, force: str = "auto"):
    """Fused gating cell for a (B, d) stream batch -> (h_new, tau, g_mean).

    ``force``: "auto" picks Pallas on TPU and the jnp ref elsewhere;
    "pallas"/"ref" override (Pallas runs in interpret mode off-TPU).  The
    batch is padded up to a multiple of the kernel block so any B works.
    """
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if not use_pallas:
        return _ref(dx, h, vol, p)
    b = dx.shape[0]
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        dx = jnp.concatenate([dx, jnp.zeros((pad,) + dx.shape[1:], dx.dtype)])
        h = jnp.concatenate([h, jnp.zeros((pad,) + h.shape[1:], h.dtype)])
        vol = jnp.concatenate([vol, jnp.zeros((pad,), vol.dtype)])
    h_new, tau, g_mean = _pallas(dx, h, vol, p, block_b=bb,
                                 interpret=not _on_tpu())
    return h_new[:b], tau[:b], g_mean[:b]
