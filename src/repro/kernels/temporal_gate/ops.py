"""jit'd public wrapper: dispatches Pallas on TPU, interpret/ref elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.temporal_gate.kernel import gate_cell as _pallas
from repro.kernels.temporal_gate.ref import gate_cell_ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_b", "force"))
def gate_cell(dx, h, vol, p, *, block_b: int = 256, force: str = "auto"):
    use_pallas = force == "pallas" or (force == "auto" and _on_tpu())
    if use_pallas:
        return _pallas(dx, h, vol, p, block_b=block_b, interpret=not _on_tpu())
    return _ref(dx, h, vol, p)
