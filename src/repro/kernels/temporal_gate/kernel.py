"""Pallas TPU fused temporal-gating cell (paper Eq. 5-6).

At fleet scale the router evaluates the gate for thousands of concurrent
streams per scheduling tick; the cell is a handful of small matmuls +
elementwise chains that XLA would execute as separate HBM round-trips.
This kernel fuses the whole step for a (BB, d) stream tile: the weight
matrices stay resident in VMEM, the tile makes a single pass, and the
batched streams ride the MXU rows.  Mirroring the ref, the three
dx-projections ride one packed (d, 3m) GEMM and the two h-projections one
(m, 2m) GEMM (column-sliced after), so the MXU sees four matmuls per tile
instead of six.

Grid = (n_b,); weights are broadcast blocks (same block for every program).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _gate_kernel(dx_ref, h_ref, vol_ref, wx_ref, ugr_ref, bg_ref, alpha_ref,
                 br_ref, uh_ref, bh_ref, wo_ref, bo_ref,
                 hout_ref, tau_ref, gmean_ref, *, m):
    dx = dx_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    vol = vol_ref[...].astype(jnp.float32)
    alpha = alpha_ref[0]

    xw = _mm(dx, wx_ref[...])                        # (BB, 3m) packed g|r|h
    hu = _mm(h, ugr_ref[...])                        # (BB, 2m) packed g|r
    g = jax.nn.sigmoid(xw[:, :m] + hu[:, :m] + bg_ref[...]
                       + (alpha * vol)[:, None])
    r = jax.nn.sigmoid(xw[:, m:2 * m] + hu[:, m:] + br_ref[...])
    cand = jnp.tanh(xw[:, 2 * m:] + _mm(r * h, uh_ref[...]) + bh_ref[...])
    h_new = (1.0 - g) * h + g * cand
    tau = jax.nn.sigmoid(_mm(h_new, wo_ref[...]) + bo_ref[...])[:, 0]
    hout_ref[...] = h_new.astype(hout_ref.dtype)
    tau_ref[...] = tau.astype(tau_ref.dtype)
    gmean_ref[...] = g.mean(axis=-1).astype(gmean_ref.dtype)


def gate_cell(dx, h, vol, p, *, block_b: int = 256, interpret: bool = False):
    """dx: (B, d); h: (B, m); vol: (B,) -> (h_new, tau, g_mean)."""
    b, d = dx.shape
    m = h.shape[1]
    bb = min(block_b, b)
    assert b % bb == 0
    nb = b // bb
    w_x = jnp.concatenate([p["w_g"], p["w_r"], p["w_h"]], axis=1)   # (d, 3m)
    u_gr = jnp.concatenate([p["u_g"], p["u_r"]], axis=1)            # (m, 2m)

    full = lambda shape: pl.BlockSpec(shape, lambda bi: tuple(0 for _ in shape))
    out = pl.pallas_call(
        functools.partial(_gate_kernel, m=m),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda bi: (bi, 0)),
            pl.BlockSpec((bb, m), lambda bi: (bi, 0)),
            pl.BlockSpec((bb,), lambda bi: (bi,)),
            full((d, 3 * m)), full((m, 2 * m)), full((m,)), full((1,)),
            full((m,)),
            full((m, m)), full((m,)),
            full((m, 1)), full((1,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, m), lambda bi: (bi, 0)),
            pl.BlockSpec((bb,), lambda bi: (bi,)),
            pl.BlockSpec((bb,), lambda bi: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(
        dx, h, vol,
        w_x, u_gr, p["b_g"], p["alpha"].reshape(1),
        p["b_r"],
        p["u_h"], p["b_h"],
        p["w_o"], p["b_o"],
    )
    return out
