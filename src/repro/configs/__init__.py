from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
