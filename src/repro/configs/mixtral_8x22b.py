"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_expert=16384 vocab=32768 [arXiv:2401.04088].

Sharding note: 8 experts don't divide the 16-way model axis, so mixtral uses
TP-within-expert (expert_mlp over "model") instead of EP; moonshot (64e) is
the EP showcase.  See DESIGN.md §4 and EXPERIMENTS.md §Perf.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attn_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384),
    sharding_overrides={
        "train": {"experts": None, "expert_mlp": "model"},
        "serve": {"experts": None, "expert_mlp": "model"},
    },
    # bf16 experts alone are 16.9 GB/chip under 16-way TP (> v5e HBM);
    # int8 expert weights at serve time fit (8.5 GB) AND halve the decode
    # weight-streaming memory term.  See EXPERIMENTS.md §Perf iteration 8.
    quant_experts_serve=True,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=128,
    attn_window=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, capacity_factor=8.0),
    attn_chunk=16,
    loss_chunk=16,
)
