"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427].
"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attn_window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    tie_embeddings=True,
    mlp_activation="gelu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,  # exercises remainder segment (5 = 1x3 + 2)
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    attn_window=16,
    layer_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
    tie_embeddings=True,
    mlp_activation="gelu",
    attn_chunk=16,
    loss_chunk=16,
)
