"""yi-34b [dense] — llama-arch GQA.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 [arXiv:2403.04652].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=128,
    attn_chunk=16,
    loss_chunk=16,
)
