"""moonshot-v1-16b-a3b [moe] — Moonlight 64-expert top-6 MoE.

48L d_model=2048 16H (kv=16) d_expert=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B].
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0),
    attn_chunk=16,
    loss_chunk=16,
)
