"""Architecture registry: dashed public ids -> config modules."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "yi-34b": "repro.configs.yi_34b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).SMOKE
