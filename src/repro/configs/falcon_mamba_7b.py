"""falcon-mamba-7b [ssm] — attention-free Mamba-1.

64L d_model=4096 vocab=65024, ssm_state=16 [arXiv:2410.05355].
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=8,       # unused (attention-free); kept nonzero for uniform code paths
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,            # mamba blocks are mixer-only
    vocab_size=65024,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=128,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    attn_chunk=16,
    loss_chunk=16,
)
