"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284].
The EnCodec frontend is a stub: ``input_specs()`` provides precomputed frame
embeddings (per assignment spec).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    embed_inputs=False,
    mlp_activation="gelu",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    embed_inputs=False,
    mlp_activation="gelu",
    attn_chunk=16,
    loss_chunk=16,
)
