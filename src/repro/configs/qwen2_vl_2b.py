"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191].
The vision frontend is a stub: ``input_specs()`` provides precomputed patch
embeddings + (t, h, w) position ids (per assignment spec).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),
    embed_inputs=False,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mrope=True,
    mrope_sections=(2, 3, 3),
    embed_inputs=False,
    attn_chunk=16,
    loss_chunk=16,
)
