"""qwen3-8b [dense] — GQA + qk-norm.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936 [hf:Qwen/Qwen3-8B].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=128,
    qk_norm=True,
    attn_chunk=16,
    loss_chunk=16,
)
