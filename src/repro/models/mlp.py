"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.params import ParamSpec


def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    s_in = d ** -0.5
    s_out = f ** -0.5 / math.sqrt(2 * cfg.num_layers)
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), stddev=s_in),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), stddev=s_in),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), stddev=s_out),
    }


def mlp_forward(ctx: Ctx, p, x, activation: str = "silu"):
    dt = ctx.compute_dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    h = act(g) * u
    h = ctx.constrain(h, "batch", "act_seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
