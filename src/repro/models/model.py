"""Top-level model: segment-scanned decoder with train / prefill / decode paths.

Layers are grouped into *segments*: the repeating ``layer_pattern`` unit is
stacked ``n_repeat`` times and driven by ``jax.lax.scan`` (one compiled body
per segment — essential to keep HLO size and CPU compile time bounded for the
512-device dry-run).  A trailing remainder (e.g. recurrentgemma's 38 = 12x3+2)
forms a second, shorter segment.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.blocks import block_apply, block_cache_specs, block_specs
from repro.models.config import ModelConfig
from repro.models.layers import Ctx, embed_specs, embed_tokens, output_weights, rmsnorm, rmsnorm_specs
from repro.models.params import ParamSpec, tree_map_specs


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------
def build_segments(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    pattern = tuple(cfg.layer_pattern)
    m = len(pattern)
    full, rem = divmod(cfg.num_layers, m)
    segs: list[tuple[tuple[str, ...], int]] = []
    if full:
        segs.append((pattern, full))
    if rem:
        segs.append((pattern[:rem], 1))
    return segs


def _stack_specs(specs: dict, n: int) -> dict:
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.stddev),
        specs,
    )


def model_specs(cfg: ModelConfig, serve: bool = False) -> dict:
    segments = []
    for pattern, n in build_segments(cfg):
        seg = {
            f"pos{i}": _stack_specs(block_specs(cfg, kind, serve=serve), n)
            for i, kind in enumerate(pattern)
        }
        segments.append(seg)
    return {
        "embed": embed_specs(cfg),
        "segments": segments,
        "final_norm": rmsnorm_specs(cfg.d_model),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    segments = []
    for pattern, n in build_segments(cfg):
        seg = {
            f"pos{i}": _stack_specs(block_cache_specs(cfg, kind, batch, seq_len), n)
            for i, kind in enumerate(pattern)
        }
        segments.append(seg)
    return {
        "length": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
        "segments": segments,
    }


# ---------------------------------------------------------------------------
# Backbone forward
# ---------------------------------------------------------------------------
def _segment_forward(ctx: Ctx, pattern, seg_params, x, *, positions, length, seg_cache, emit_cache):
    cfg = ctx.cfg

    def body(x_carry, xs):
        layer_p, layer_c = xs
        new_c = {}
        aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            c = layer_c[f"pos{i}"] if layer_c is not None else None
            x_carry, nc, aux = block_apply(
                ctx, kind, layer_p[f"pos{i}"], x_carry,
                positions=positions, length=length, cache=c, emit_cache=emit_cache,
            )
            if nc is not None:
                new_c[f"pos{i}"] = nc
            aux_total = aux_total + aux
        x_carry = ctx.constrain(x_carry, "batch", "act_seq_sp", "act_embed")
        return x_carry, (new_c, aux_total)

    if cfg.remat and ctx.mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (seg_params, seg_cache)
    x, (new_cache, aux) = jax.lax.scan(body, x, xs)
    if not new_cache:
        new_cache = None
    return x, new_cache, jnp.sum(aux)


def forward(
    ctx: Ctx,
    params: dict,
    inputs: dict,
    *,
    cache: Optional[dict] = None,
    emit_cache: bool = False,
):
    """inputs: {"tokens": (B,S)} or {"embeddings": (B,S,d)}; optional
    {"positions": (B,S) or (B,3,S)}.  Returns (hidden (B,S,d), new_cache, aux)."""
    cfg = ctx.cfg
    dt = ctx.compute_dtype

    if cfg.embed_inputs:
        x = embed_tokens(ctx, params["embed"], inputs["tokens"])
        if cfg.family == "hybrid":  # gemma-style embedding scale
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
        b, s = inputs["tokens"].shape
    else:
        x = inputs["embeddings"].astype(dt)
        x = ctx.constrain(x, "batch", "act_seq", "act_embed")
        b, s = x.shape[0], x.shape[1]

    length = cache["length"] if cache is not None else None
    if "positions" in inputs:
        positions = inputs["positions"]
    elif ctx.mode == "decode":
        # scalar length = whole-batch progress; (B,) = per-row slab progress
        pos = length[None, None] if length.ndim == 0 else length[:, None]
        positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, 1))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, s))

    new_segments = []
    aux_total = jnp.zeros((), jnp.float32)
    for seg_idx, (pattern, n) in enumerate(build_segments(cfg)):
        seg_params = params["segments"][seg_idx]
        seg_cache = cache["segments"][seg_idx] if cache is not None else None
        x, new_seg, aux = _segment_forward(
            ctx, pattern, seg_params, x,
            positions=positions, length=length, seg_cache=seg_cache, emit_cache=emit_cache,
        )
        new_segments.append(new_seg)
        aux_total = aux_total + aux

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    new_cache = None
    if any(s is not None for s in new_segments):
        new_len = (length + s) if length is not None else jnp.asarray(s, jnp.int32)
        new_cache = {"length": new_len, "segments": new_segments}
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Losses / heads
# ---------------------------------------------------------------------------
def chunked_ce_loss(ctx: Ctx, x, w_out, labels, mask=None):
    """Fused lm-head + cross-entropy, scanned over sequence chunks so the
    (B, chunk, V) logits buffer stays bounded and vocab-sharded."""
    cfg = ctx.cfg
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)
    w = w_out.astype(ctx.compute_dtype)

    def body(carry, xs):
        x_blk, l_blk, m_blk = xs
        logits = jnp.einsum("bcd,dv->bcv", x_blk, w)
        logits = ctx.constrain(logits, "batch", None, "vocab").astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, l_blk[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m_blk
        return (carry[0] + nll.sum(), carry[1] + m_blk.sum()), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (total, denom), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc, mc))
    return total / jnp.maximum(denom, 1.0)


def logits_last(ctx: Ctx, x_last, w_out):
    """x_last: (B, 1, d) -> (B, V) float32 logits."""
    logits = jnp.einsum("bod,dv->bov", x_last, w_out.astype(ctx.compute_dtype))
    return ctx.constrain(logits[:, 0], "batch", "vocab").astype(jnp.float32)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def loss_fn(ctx: Ctx, params, batch, aux_weight: float = 0.01):
    x, _, aux = forward(ctx, params, batch)
    w_out = output_weights(ctx.cfg, params["embed"])
    ce = chunked_ce_loss(ctx, x, w_out, batch["labels"], batch.get("mask"))
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def prefill(ctx: Ctx, params, batch):
    ctx = dataclasses.replace(ctx, mode="prefill")
    x, cache, _ = forward(ctx, params, batch, emit_cache=True)
    w_out = output_weights(ctx.cfg, params["embed"])
    return logits_last(ctx, x[:, -1:], w_out), cache


def decode_step(ctx: Ctx, params, cache, batch):
    ctx = dataclasses.replace(ctx, mode="decode")
    x, new_cache, _ = forward(ctx, params, batch, cache=cache)
    w_out = output_weights(ctx.cfg, params["embed"])
    return logits_last(ctx, x, w_out), new_cache
