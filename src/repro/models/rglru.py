"""RG-LRU recurrent mixer (RecurrentGemma / Griffin recurrent block).

Block structure (Griffin):
  x -> [linear -> temporal conv -> RG-LRU]  (recurrent branch)
    -> [linear -> GeLU]                      (gate branch)
  out = W_out (branch_rec * branch_gate)

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
  a_t = exp(-c * softplus(Lambda) * r_t)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.params import ParamSpec

_C = 8.0  # RG-LRU decay temperature (Griffin)


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width
    cw = cfg.rglru.conv_width
    s_in = d ** -0.5
    return {
        "w_rec_in": ParamSpec((d, w), ("embed", "rglru_width"), stddev=s_in),
        "w_gate_in": ParamSpec((d, w), ("embed", "rglru_width"), stddev=s_in),
        "conv_w": ParamSpec((cw, w), ("conv", "rglru_width"), stddev=cw ** -0.5),
        "conv_b": ParamSpec((w,), ("rglru_width",), init="zeros"),
        "w_a": ParamSpec((w, w), ("rglru_width", None), stddev=w ** -0.5),
        "b_a": ParamSpec((w,), ("rglru_width",), init="zeros"),
        "w_x": ParamSpec((w, w), ("rglru_width", None), stddev=w ** -0.5),
        "b_x": ParamSpec((w,), ("rglru_width",), init="zeros"),
        "lambda_p": ParamSpec((w,), ("rglru_width",), init="ones"),
        "w_out": ParamSpec(
            (w, d), ("rglru_width", "embed"),
            stddev=w ** -0.5 / math.sqrt(2 * cfg.num_layers),
        ),
    }


def rglru_scan_ref(x, rgate, igate, log_a_base, h0=None, chunk: int = 1):
    """RG-LRU scan with an optional chunked-unrolled time loop (default 1 —
    chunk unrolling measured slower on the XLA path, see
    ssm.selective_scan_ref; the Pallas kernel repro.kernels.rglru is the
    TPU performance path).  Padded steps have r = 0 => a = 1, i*x = 0 =>
    h preserved.

    x, rgate, igate: (B, S, W) f32; log_a_base: (W,) = -c*softplus(Lambda) < 0.
    Returns y: (B, S, W), h_final: (B, W).
    """
    b, s, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    chunk = max(1, min(chunk, s))
    pad = (-s) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        x, rgate, igate = map(zpad, (x, rgate, igate))
    sp = s + pad
    nc = sp // chunk

    def to_chunks(a):
        return jnp.moveaxis(a.astype(jnp.float32).reshape(b, nc, chunk, w), 1, 0)

    xs = tuple(to_chunks(a) for a in (x, rgate, igate))

    def chunk_body(h, inp):
        x_c, r_c, i_c = inp
        ys = []
        for t in range(chunk):  # unrolled
            a = jnp.exp(log_a_base[None] * r_c[:, t])
            h = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (
                i_c[:, t] * x_c[:, t]
            )
            ys.append(h)
        return h, jnp.stack(ys, axis=1)

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, w)
    return y[:, :s], h_final


def _causal_conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        x_pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = x_pad[:, -(k - 1) :, :] if k > 1 else None
    return out + b[None, None, :], new_state


def rglru_forward(ctx: Ctx, p, x, *, cache=None):
    """cache: {"conv": (B, K-1, W), "h": (B, W), "length"} for decode."""
    cfg = ctx.cfg
    dt = ctx.compute_dtype

    rec = jnp.einsum("bsd,dw->bsw", x, p["w_rec_in"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"].astype(dt)))
    rec = ctx.constrain(rec, "batch", "act_seq", "rglru_width")

    conv_state = cache["conv"] if cache is not None else None
    rec, new_conv = _causal_conv(rec, p["conv_w"].astype(dt), p["conv_b"].astype(dt), conv_state)

    rgate = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", rec, p["w_a"].astype(dt)).astype(jnp.float32)
        + p["b_a"].astype(jnp.float32)
    )
    igate = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", rec, p["w_x"].astype(dt)).astype(jnp.float32)
        + p["b_x"].astype(jnp.float32)
    )
    log_a_base = -_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))

    h0 = cache["h"] if cache is not None else None
    y, h_final = rglru_scan_ref(rec.astype(jnp.float32), rgate, igate, log_a_base, h0)
    y = y.astype(dt) * gate
    y = ctx.constrain(y, "batch", "act_seq", "rglru_width")
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt))

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_final, "length": cache["length"] + x.shape[1]}
    elif ctx.mode == "prefill":
        new_cache = {
            "conv": new_conv,
            "h": h_final,
            "length": jnp.asarray(x.shape[1], jnp.int32),
        }
    return out, new_cache
