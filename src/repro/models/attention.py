"""GQA attention: chunked (memory-efficient) prefill/train + cache decode.

The pure-XLA path implements flash-attention-style online softmax with a
double (q-chunk x kv-chunk) scan so the live score buffer is bounded at
``q_chunk x kv_chunk`` regardless of sequence length.  Windowed variants
(mixtral SWA, recurrentgemma local attention) gather only the window slice
per q-chunk, keeping compute O(S*W).  The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU performance path; this module is
also its oracle.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Ctx, apply_mrope, apply_rope, rmsnorm
from repro.models.params import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig) -> dict:
    """Fused-head weight layouts (d, H*hd): the flattened head dim is always
    a multiple of the TP degree even when head counts (56, 12, 24, ...) are
    not, so the weights shard evenly over "model"."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_in = d ** -0.5
    s_out = (h * hd) ** -0.5 / math.sqrt(2 * cfg.num_layers)
    specs = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads_flat"), stddev=s_in),
        "wk": ParamSpec((d, kv * hd), ("embed", "heads_flat"), stddev=s_in),
        "wv": ParamSpec((d, kv * hd), ("embed", "heads_flat"), stddev=s_in),
        "wo": ParamSpec((h * hd, d), ("heads_flat", "embed"), stddev=s_out),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h * hd,), ("heads_flat",), init="zeros")
        specs["bk"] = ParamSpec((kv * hd,), ("heads_flat",), init="zeros")
        specs["bv"] = ParamSpec((kv * hd,), ("heads_flat",), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return specs


def _head_rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked causal attention (full or windowed), GQA-aware.
# q: (B, Sq, H, D)  k/v: (B, Sk, KV, D)
# ---------------------------------------------------------------------------
def chunked_attention(
    q,
    k,
    v,
    positions,
    *,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
):
    """positions: (B, S) int32 token positions for BOTH q and k (self-attn).

    Masks are derived from the runtime ``positions`` array (not from loop
    counters): this keeps XLA from hoisting per-iteration masks out of the
    kv scan into a stacked O(nq*nk*Cq*Ck) pred buffer.
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = d ** -0.5
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad seq dims up to chunk multiples; padded keys get position +inf so
    # causality masks them; padded queries are sliced off the output.
    sq_pad = (-sq) % q_chunk
    sk_pad = (-sk) % k_chunk
    q_pos = positions.astype(jnp.int32)
    k_pos = positions.astype(jnp.int32)
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, sq_pad)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, sk_pad)), constant_values=2**30)
    orig_sq, sq, sk = sq, sq + sq_pad, sk + sk_pad
    nq = sq // q_chunk

    qg = q.reshape(b, nq, q_chunk, kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    qp = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)  # (nq, B, Cq)
    # qg: (nq, B, KV, G, Cq, D)

    if window is not None and sk > window + q_chunk:
        out = _windowed_blocks(qg, qp, k, v, k_pos, window, q_chunk, scale)
    else:
        out = _full_blocks(qg, qp, k, v, k_pos, window, k_chunk, scale)
    # out: (nq, B, KV, G, Cq, D) -> (B, Sq, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)
    return out[:, :orig_sq]


def _online_softmax_block(carry, scores, v_blk):
    """scores: (..., Cq, Ck) f32; v_blk: (B, KV, Ck, D)."""
    m_prev, l_prev, acc = carry
    m_cur = jnp.max(scores, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum(
        "bkgqc,bkcd->bkgqd", p.astype(v_blk.dtype), v_blk
    ).astype(jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def _full_blocks(qg, qp, k, v, k_pos, window, k_chunk, scale):
    nq, b, kvh, g, cq, d = qg.shape
    sk = k.shape[1]
    nk = sk // k_chunk
    assert sk % k_chunk == 0, (sk, k_chunk)
    kb = k.transpose(0, 2, 1, 3).reshape(b, kvh, nk, k_chunk, d).transpose(2, 0, 1, 3, 4)
    vb = v.transpose(0, 2, 1, 3).reshape(b, kvh, nk, k_chunk, d).transpose(2, 0, 1, 3, 4)
    kpb = k_pos.reshape(b, nk, k_chunk).transpose(1, 0, 2)  # (nk, B, Ck)
    # kb/vb: (nk, B, KV, Ck, D)

    def q_body(_, q_xs):
        q_blk, q_pos = q_xs  # (B, KV, G, Cq, D), (B, Cq)

        def k_body(carry, k_xs):
            k_blk, v_blk, kp = k_xs
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk, k_blk).astype(jnp.float32)
            s = s * scale
            mask = q_pos[:, :, None] >= kp[:, None, :]  # (B, Cq, Ck) data-dep
            if window is not None:
                mask &= q_pos[:, :, None] - kp[:, None, :] < window
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            return _online_softmax_block(carry, s, v_blk), None

        init = (
            jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, cq), jnp.float32),
            jnp.zeros((b, kvh, g, cq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(k_body, init, (kb, vb, kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q_blk.dtype)

    _, out = jax.lax.scan(q_body, None, (qg, qp))
    return out


def _windowed_blocks(qg, qp, k, v, k_pos, window, q_chunk, scale):
    """Gather only the (window + q_chunk) key slice per q block: O(S*W)."""
    nq, b, kvh, g, cq, d = qg.shape
    sk = k.shape[1]
    span = min(window + q_chunk, sk)
    kt = k.transpose(0, 2, 1, 3)  # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    def q_body(_, q_xs):
        qi, q_blk, q_pos = q_xs
        q_start = qi * q_chunk
        k_start = jnp.clip(q_start + q_chunk - span, 0, max(sk - span, 0))
        k_blk = jax.lax.dynamic_slice_in_dim(kt, k_start, span, axis=2)
        v_blk = jax.lax.dynamic_slice_in_dim(vt, k_start, span, axis=2)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, k_start, span, axis=1)  # (B, span)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk, k_blk).astype(jnp.float32) * scale
        mask = (q_pos[:, :, None] >= kp[:, None, :]) & (
            q_pos[:, :, None] - kp[:, None, :] < window
        )
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bkgqc,bkcd->bkgqd", (p / jnp.maximum(l, 1e-30)).astype(v_blk.dtype), v_blk)
        return None, out

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg, qp))
    return out


def decode_attention(q, k_cache, v_cache, *, length, window: Optional[int] = None):
    """Single-token attention against a cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D); length: scalar int —
    number of valid cache entries (the cache may be a rolling window buffer,
    in which case every slot < min(length, S) is valid) — or (B,) int for
    per-row progress (continuous-batching cache slabs, where co-batched
    requests joined at different times).
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = d ** -0.5
    qg = q.reshape(b, kvh, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    lengths = jnp.broadcast_to(jnp.atleast_1d(length), (b,))
    valid = jnp.arange(s)[None, :] < jnp.minimum(lengths, s)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# Full attention block forward
# ---------------------------------------------------------------------------
def attn_forward(
    ctx: Ctx,
    p,
    x,
    *,
    positions,          # (B, S) int32 or (B, 3, S) for mrope
    cache=None,         # dict(k, v, length) or None
    cache_out_len: Optional[int] = None,  # prefill: emit a cache of this length
):
    cfg = ctx.cfg
    dt = ctx.compute_dtype
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = _head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = _head_rmsnorm(k, p["k_norm"], cfg.norm_eps)

    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        pos_scalar = positions[:, 0]  # temporal stream drives causality
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos_scalar = positions

    q = ctx.constrain(q, "batch", "act_seq", "heads", "head_dim")
    k = ctx.constrain(k, "batch", "act_seq", "kv_heads", "head_dim")
    v = ctx.constrain(v, "batch", "act_seq", "kv_heads", "head_dim")

    new_cache = None
    if ctx.mode == "decode":
        assert cache is not None
        idx = cache["length"]  # scalar int32 (or (B,): per-row slab progress)
        cache_len = cache["k"].shape[1]
        # rolling-window write position (== idx for full caches)
        wpos = jnp.mod(idx, cache_len)
        if jnp.ndim(idx) == 0:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, wpos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, wpos, axis=1)
        else:
            # per-row write position: each batch row owns its own progress
            # through the cache slab (continuous-batching decode)
            hit = jnp.arange(cache_len)[None, :] == wpos[:, None]  # (B, C)
            k_cache = jnp.where(hit[:, :, None, None], k, cache["k"])
            v_cache = jnp.where(hit[:, :, None, None], v, cache["v"])
        k_cache = ctx.constrain(k_cache, "cache_batch", "cache_seq", "cache_kv", "cache_dim")
        v_cache = ctx.constrain(v_cache, "cache_batch", "cache_seq", "cache_kv", "cache_dim")
        out = decode_attention(q, k_cache, v_cache, length=idx + 1, window=cfg.attn_window)
        new_cache = {"k": k_cache, "v": v_cache, "length": idx + 1}
    else:
        out = chunked_attention(
            q, k, v, pos_scalar,
            window=cfg.attn_window,
            q_chunk=cfg.attn_chunk,
            k_chunk=cfg.attn_chunk,
        )
        if cache_out_len is not None:
            keep = min(cache_out_len, s)
            k_keep = jax.lax.slice_in_dim(k, s - keep, s, axis=1)
            v_keep = jax.lax.slice_in_dim(v, s - keep, s, axis=1)
            if keep < cache_out_len:
                pad = [(0, 0), (0, cache_out_len - keep), (0, 0), (0, 0)]
                k_keep = jnp.pad(k_keep, pad)
                v_keep = jnp.pad(v_keep, pad)
            new_cache = {
                "k": ctx.constrain(k_keep, "cache_batch", "cache_seq", "cache_kv", "cache_dim"),
                "v": ctx.constrain(v_keep, "cache_batch", "cache_seq", "cache_kv", "cache_dim"),
                "length": jnp.asarray(s, jnp.int32),
            }

    out = ctx.constrain(out, "batch", "act_seq", "heads", "head_dim")
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * hd), p["wo"].astype(dt))
    return y, new_cache
