"""Model configuration dataclasses covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    min_capacity: int = 16


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 mixer."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent mixer (RecurrentGemma / Griffin)."""
    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    block_width: int = 256  # temporal chunk for the blocked scan


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attn_window: Optional[int] = None     # None = full causal; int = sliding window
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False                    # qwen2-vl multimodal rope
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    # layer mixture; pattern repeats over layers: entries in {attn, rglru, ssm}
    layer_pattern: tuple[str, ...] = ("attn",)
    # sub-modules
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # embeddings
    embed_inputs: bool = True              # False: frontend stub feeds embeddings
    tie_embeddings: bool = False
    mlp_activation: str = "silu"           # silu | gelu (recurrentgemma GeGLU)
    # full-attention caches reserve this many decode slots past the prompt
    # (without it the first decoded token wraps to slot 0 and overwrites the
    # first prompt token — found by the prefill/decode consistency tests)
    decode_headroom: int = 64
    # numerics / compilation
    norm_eps: float = 1e-6
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"           # serve paths cast to compute dtype
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 1024                 # kv-chunk for memory-efficient attention
    loss_chunk: int = 512                  # seq-chunk for the fused lm-head/CE loss
    # per-mode sharding rule overrides: {"train": {...}, "serve": {...}}
    sharding_overrides: Mapping[str, Mapping[str, object]] = dataclasses.field(
        default_factory=dict
    )
    # Pallas kernels: "auto" uses them on TPU only; "on"/"off" force.
    kernels: str = "auto"
    # int8 expert weights at serve time (mixtral-class models whose bf16
    # experts alone exceed 16 GB/chip under 16-way TP; also halves the
    # weight-streaming memory term of MoE decode)
    quant_experts_serve: bool = False

    # ------------------------------------------------------------------
    @property
    def dt_rank(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def lru_width(self) -> int:
        if self.rglru is None:
            return 0
        return self.rglru.lru_width or self.d_model

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.num_layers))

    @property
    def uniform_layers(self) -> bool:
        return len(set(self.layer_kinds())) == 1

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does not grow linearly with full context."""
        kinds = set(self.layer_kinds())
        if kinds <= {"ssm", "rglru"}:
            return True
        # attention layers are sub-quadratic iff windowed
        return self.attn_window is not None

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (matches the built spec tree)."""
        n = 0
        if self.embed_inputs:
            n += self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind in self.layer_kinds():
            n += self.d_model  # pre-mixer norm
            if kind == "attn":
                qkv = self.d_model * self.head_dim * (self.num_heads + 2 * self.num_kv_heads)
                o = self.num_heads * self.head_dim * self.d_model
                n += qkv + o
                if self.qkv_bias:
                    n += self.head_dim * (self.num_heads + 2 * self.num_kv_heads)
                if self.qk_norm:
                    n += 2 * self.head_dim
            elif kind == "ssm":
                d_in, r, s = self.d_inner, self.dt_rank, self.ssm.d_state
                n += self.d_model * 2 * d_in            # in_proj
                n += self.ssm.d_conv * d_in + d_in      # conv w + b
                n += d_in * (r + 2 * s)                 # x_proj
                n += r * d_in + d_in                    # dt_proj
                n += d_in * s + d_in                    # A_log, D
                n += d_in * self.d_model                # out_proj
            elif kind == "rglru":
                w = self.lru_width
                n += self.d_model * w * 2               # branch projections
                n += self.rglru.conv_width * w + w      # temporal conv w + b
                n += 2 * (w * w + w)                    # recurrence/input gates
                n += w                                  # Lambda param
                n += w * self.d_model                   # out proj
            if kind == "attn" or kind == "rglru":
                # MLP follows attention/rglru mixers (ssm blocks are mixer-only)
                n += self.d_model  # pre-mlp norm
                if self.moe is not None:
                    e = self.moe
                    n += self.d_model * e.num_experts   # router
                    ff = 3 * self.d_model * e.d_expert
                    n += (e.num_experts if not active_only else e.top_k) * ff
                else:
                    n += 3 * self.d_model * self.d_ff
        n += self.d_model  # final norm
        return n
