"""Mamba-1 selective SSM mixer (falcon-mamba-7b).

The XLA path uses a sequential ``lax.scan`` over time (numerically exact, one
compiled body regardless of sequence length); the TPU performance path is the
blocked Pallas kernel in ``repro.kernels.mamba_scan`` which carries state
across VMEM time tiles.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.params import ParamSpec


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    r = cfg.dt_rank
    st = cfg.ssm.d_state
    cw = cfg.ssm.d_conv
    s_in = d ** -0.5
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner"), stddev=s_in),
        "conv_w": ParamSpec((cw, di), ("conv", "inner"), stddev=cw ** -0.5),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * st), ("inner", None), stddev=di ** -0.5),
        "dt_proj": ParamSpec((r, di), ("dt_rank", "inner"), stddev=r ** -0.5),
        "dt_bias": ParamSpec((di,), ("inner",), init="zeros"),
        "A_log": ParamSpec((di, st), ("inner", "state"), init="zeros"),
        "D": ParamSpec((di,), ("inner",), init="ones"),
        "out_proj": ParamSpec(
            (di, d), ("inner", "embed"),
            stddev=di ** -0.5 / math.sqrt(2 * cfg.num_layers),
        ),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C); state: (B,K-1,C) or None."""
    k = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        x_pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = x_pad[:, -(k - 1) :, :] if k > 1 else None
    return out + b[None, None, :], new_state


def selective_scan_ref(x, dt, Bmat, Cmat, A, D, h0=None, chunk: int = 1):
    """Selective scan with an optional chunked-unrolled time loop.

    NOTE (EXPERIMENTS.md §Perf iteration 4, REFUTED): unrolling chunks does
    NOT cut HBM traffic on the XLA path — the per-step y_t = C·h reduction
    breaks the elementwise fusion chain, so the state materializes every
    step regardless (measured +31% from stacking overhead at chunk=16).
    Default is therefore chunk=1 (plain scan); the real fix is the Pallas
    kernel (repro.kernels.mamba_scan) whose VMEM-resident state makes the
    scan traffic = stream inputs/outputs once per layer.

    x, dt: (B, S, Di); Bmat, Cmat: (B, S, N); A: (Di, N); D: (Di,).
    Returns y: (B, S, Di) f32, h_final: (B, Di, N) f32.
    """
    b, s, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    chunk = max(1, min(chunk, s))
    pad = (-s) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x, dt, Bmat, Cmat = map(zpad, (x, dt, Bmat, Cmat))
    sp = s + pad
    nc = sp // chunk

    def to_chunks(a):
        return jnp.moveaxis(
            a.astype(jnp.float32).reshape(b, nc, chunk, -1), 1, 0
        )  # (nc, B, chunk, F)

    xs = tuple(to_chunks(a) for a in (x, dt, Bmat, Cmat))

    def chunk_body(h, inp):
        x_c, dt_c, b_c, c_c = inp
        ys = []
        for t in range(chunk):  # unrolled: intermediates stay fused
            da = jnp.exp(dt_c[:, t, :, None] * A[None])
            dbx = dt_c[:, t, :, None] * b_c[:, t, None, :] * x_c[:, t, :, None]
            h = da * h + dbx
            ys.append(jnp.einsum("bdn,bn->bd", h, c_c[:, t]) + D[None] * x_c[:, t])
        return h, jnp.stack(ys, axis=1)  # (B, chunk, Di)

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, di)
    return y[:, :s], h_final


def ssm_forward(ctx: Ctx, p, x, *, cache=None):
    """cache: {"conv": (B, K-1, Di), "h": (B, Di, N), "length"} for decode."""
    cfg = ctx.cfg
    dt_ = ctx.compute_dtype
    di = cfg.d_inner
    r = cfg.dt_rank
    n = cfg.ssm.d_state

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = ctx.constrain(xs, "batch", "act_seq", "inner")

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), conv_state)
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bse,ef->bsf", xs, p["x_proj"].astype(dt_))
    dt_raw, Bmat, Cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt_full = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_raw, p["dt_proj"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = cache["h"] if cache is not None else None
    y, h_final = selective_scan_ref(xs, dt_full, Bmat, Cmat, A, p["D"].astype(jnp.float32), h0)
    y = y.astype(dt_) * jax.nn.silu(z)
    y = ctx.constrain(y, "batch", "act_seq", "inner")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_final, "length": cache["length"] + x.shape[1]}
    elif ctx.mode == "prefill":
        new_cache = {
            "conv": new_conv,
            "h": h_final,
            "length": jnp.asarray(x.shape[1], jnp.int32),
        }
    return out, new_cache
