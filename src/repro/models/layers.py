"""Shared layers: norms, embeddings, RoPE / M-RoPE, forward context."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding.rules import ShardingRules


# ---------------------------------------------------------------------------
# Forward context: carries config + sharding rules so layers can place
# sharding constraints.  rules=None (smoke tests / single device) is a no-op.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    rules: Optional[ShardingRules] = None
    mode: str = "train"  # train | prefill | decode

    @property
    def compute_dtype(self):
        return jnp.dtype(self.cfg.compute_dtype)

    def constrain(self, x, *logical_axes):
        if self.rules is None:
            return x
        # NOTE: constraints are intentionally NOT divisibility-fitted.  A
        # forced non-divisible constraint costs a padded reshard ("involuntary
        # full rematerialization" warning), but *dropping* it lets GSPMD pick
        # far worse layouts for odd head counts (musicgen kv=24, yi-34b H=56:
        # up to 10x regressions) — measured in EXPERIMENTS.md §Perf it.1/it.6.
        return jax.lax.with_sharding_constraint(x, self.rules.spec(logical_axes))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("act_embed",), init="ones")}


def rmsnorm(p, x, eps: float):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding + output head
# ---------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig) -> dict:
    out = {}
    if cfg.embed_inputs:
        out["tok"] = ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="normal", stddev=1.0
        )
    if not cfg.tie_embeddings:
        out["out"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="normal",
            stddev=cfg.d_model ** -0.5,
        )
    return out


def embed_tokens(ctx: Ctx, p, tokens):
    emb = p["tok"].astype(ctx.compute_dtype)
    x = jnp.take(emb, tokens, axis=0)
    return ctx.constrain(x, "batch", "act_seq", "act_embed")


def output_weights(cfg: ModelConfig, embed_params):
    if cfg.tie_embeddings:
        return embed_params["tok"].T  # (d, vocab)
    return embed_params["out"]


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """M-RoPE (Qwen2-VL): frequency channels split over (t, h, w) position ids.

    x: (B, S, H, D); positions3: (B, 3, S) int32; sections sums to D//2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # angles per position stream: (B, 3, S, half)
    angles_all = positions3[..., None].astype(jnp.float32) * freqs
    # select which stream drives each frequency channel
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (half,)
    angles = jnp.transpose(angles_all, (0, 2, 3, 1))  # (B, S, half, 3)
    angles = jnp.sum(angles * jax.nn.one_hot(sec_id, 3, dtype=jnp.float32), axis=-1)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)
