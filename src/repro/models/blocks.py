"""Decoder block assembly: attn / rglru / ssm mixers + (optional) MLP/MoE."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models.attention import attn_forward, attn_specs
from repro.models.config import ModelConfig
from repro.models.layers import Ctx, rmsnorm, rmsnorm_specs
from repro.models.mlp import mlp_forward, mlp_specs
from repro.models.moe import moe_forward, moe_specs
from repro.models.params import ParamSpec
from repro.models.rglru import rglru_forward, rglru_specs
from repro.models.ssm import ssm_forward, ssm_specs


def block_specs(cfg: ModelConfig, kind: str, serve: bool = False) -> dict:
    specs = {"norm1": rmsnorm_specs(cfg.d_model)}
    if kind == "attn":
        specs["attn"] = attn_specs(cfg)
    elif kind == "rglru":
        specs["rglru"] = rglru_specs(cfg)
    elif kind == "ssm":
        specs["ssm"] = ssm_specs(cfg)
    else:
        raise ValueError(kind)
    if kind in ("attn", "rglru"):
        specs["norm2"] = rmsnorm_specs(cfg.d_model)
        if cfg.moe is not None:
            specs["mlp"] = moe_specs(cfg, quantized=serve and cfg.quant_experts_serve)
        else:
            specs["mlp"] = mlp_specs(cfg)
    return specs


def attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.attn_window is not None:
        return min(cfg.attn_window, seq_len)  # rolling window cache
    return seq_len + cfg.decode_headroom


def block_cache_specs(cfg: ModelConfig, kind: str, batch: int, seq_len: int) -> dict:
    """Cache layout per layer (as ParamSpec so dry-run can use ShapeDtypeStruct)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if kind == "attn":
        c = attn_cache_len(cfg, seq_len)
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        ax = ("cache_batch", "cache_seq", "cache_kv", "cache_dim")
        return {
            "k": ParamSpec((batch, c, kv, hd), ax, dtype=dt, init="zeros"),
            "v": ParamSpec((batch, c, kv, hd), ax, dtype=dt, init="zeros"),
        }
    if kind == "ssm":
        di, st, cw = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
        return {
            "conv": ParamSpec((batch, cw - 1, di), ("cache_batch", None, "inner"), dtype=dt, init="zeros"),
            "h": ParamSpec((batch, di, st), ("cache_batch", "inner", "state"), dtype=jnp.float32, init="zeros"),
        }
    if kind == "rglru":
        w, cw = cfg.lru_width, cfg.rglru.conv_width
        return {
            "conv": ParamSpec((batch, cw - 1, w), ("cache_batch", None, "rglru_width"), dtype=dt, init="zeros"),
            "h": ParamSpec((batch, w), ("cache_batch", "rglru_width"), dtype=jnp.float32, init="zeros"),
        }
    raise ValueError(kind)


def block_apply(
    ctx: Ctx,
    kind: str,
    p: dict,
    x,
    *,
    positions=None,
    length=None,
    cache: Optional[dict] = None,
    emit_cache: bool = False,
):
    """Returns (x, new_cache_or_None, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)

    if kind == "attn":
        c = dict(cache, length=length) if cache is not None else None
        out_len = attn_cache_len(cfg, x.shape[1]) if emit_cache else None
        y, new_cache = attn_forward(ctx, p["attn"], h, positions=positions, cache=c, cache_out_len=out_len)
    elif kind == "rglru":
        c = dict(cache, length=length) if cache is not None else None
        y, new_cache = rglru_forward(ctx, p["rglru"], h, cache=c)
    elif kind == "ssm":
        c = dict(cache, length=length) if cache is not None else None
        y, new_cache = ssm_forward(ctx, p["ssm"], h, cache=c)
    else:
        raise ValueError(kind)

    if new_cache is not None:
        new_cache.pop("length", None)
    x = x + y

    if kind in ("attn", "rglru"):
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            y2, aux = moe_forward(ctx, p["mlp"], h2)
        else:
            y2 = mlp_forward(ctx, p["mlp"], h2, activation=cfg.mlp_activation)
        x = x + y2

    return x, new_cache, aux
