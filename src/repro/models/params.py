"""Parameter-spec system: declare params as specs, materialize lazily.

A model is described by a pytree of :class:`ParamSpec`.  From the same tree we
derive (a) real initialized arrays for smoke tests / small-scale training,
(b) ``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run (zero
allocation), and (c) ``NamedSharding`` trees from logical axis names.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import ShardingRules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"      # normal | zeros | ones | embed
    stddev: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def _eff_dtype(spec, dtype_override):
    """dtype_override applies to float leaves only (int8 quantized weights
    and int32 state keep their storage dtype)."""
    if dtype_override is not None and jnp.issubdtype(spec.dtype, jnp.floating):
        return dtype_override
    return spec.dtype


def shape_dtypes(tree, dtype_override=None, shardings=None):
    """ShapeDtypeStruct tree (optionally with attached shardings)."""
    if shardings is None:
        return tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(s.shape, _eff_dtype(s, dtype_override)), tree
        )
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, _eff_dtype(s, dtype_override), sharding=sh
        ),
        tree,
        shardings,
        is_leaf=is_spec,
    )


def shardings(tree, mesh, rules: ShardingRules):
    return tree_map_specs(lambda s: rules.fitted_sharding(mesh, s.axes, s.shape), tree)


def specs_pspec(tree, rules: ShardingRules):
    return tree_map_specs(lambda s: rules.spec(s.axes), tree)


def init_params(tree, rng, dtype_override=None):
    """Materialize real arrays (smoke tests, examples, small training runs)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, rngs):
        dtype = _eff_dtype(spec, dtype_override)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * spec.stddev).astype(dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(tree, is_leaf=is_spec))


def fan_in_normal(shape: Sequence[int], fan_in: int) -> ParamSpec:
    raise NotImplementedError  # placeholder guard; builders construct ParamSpec directly
