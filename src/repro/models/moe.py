"""Token-choice top-k MoE with capacity-bounded gather dispatch (EP-shardable).

Dispatch strategy (production-style, not dense-all-experts):
  1. router logits -> top-k expert ids + weights per token
  2. position-in-expert via cumsum over the flattened (token*k) assignment
  3. tokens above capacity C = ceil(T*k/E * capacity_factor) are dropped
  4. gather to (E, C, d), grouped einsum against (E, d, f) expert weights,
     scatter-gather back with combine weights.

Expert weight dim 0 is the "experts" logical axis (EP over the model mesh
axis); the d_model dim carries "expert_in" so memory-constrained serving
configs (mixtral decode) can FSDP-shard expert weights over "data".
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.params import ParamSpec


import jax.numpy as _jnp

_EXPERT_WEIGHTS = ("w_gate", "w_up", "w_down")


def moe_specs(cfg: ModelConfig, quantized: bool = False) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_expert
    s_in = d ** -0.5
    s_out = f ** -0.5 / math.sqrt(2 * cfg.num_layers)
    wdt = _jnp.int8 if quantized else _jnp.float32
    specs = {
        "router": ParamSpec((d, e.num_experts), ("embed", None), stddev=s_in),
        "w_gate": ParamSpec((e.num_experts, d, f), ("experts", "expert_in", "expert_mlp"), dtype=wdt, stddev=s_in),
        "w_up": ParamSpec((e.num_experts, d, f), ("experts", "expert_in", "expert_mlp"), dtype=wdt, stddev=s_in),
        "w_down": ParamSpec((e.num_experts, f, d), ("experts", "expert_mlp", "expert_in"), dtype=wdt, stddev=s_out),
    }
    if quantized:
        for name in _EXPERT_WEIGHTS:
            specs[name + "_scale"] = ParamSpec(
                (e.num_experts, 1, 1), ("experts", None, None), init="ones"
            )
    return specs


def quantize_expert_params(p: dict) -> dict:
    """fp32/bf16 expert weights -> int8 + per-expert absmax scales."""
    out = dict(p)
    for name in _EXPERT_WEIGHTS:
        w = jnp.asarray(p[name], jnp.float32)
        scale = jnp.max(jnp.abs(w), axis=(1, 2), keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        out[name] = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        out[name + "_scale"] = scale.astype(jnp.float32)
    return out


def _expert_w(p: dict, name: str, dt):
    w = p[name]
    if w.dtype == jnp.int8:
        return (w.astype(dt) * p[name + "_scale"].astype(dt))
    return w.astype(dt)


def moe_forward(ctx: Ctx, p, x):
    """Grouped (per-data-shard) dispatch: tokens are viewed as (G, t/G) with
    G = the DP shard count, and every dispatch op (cumsum, scatter, gather)
    is per-group — GSPMD keeps them local to the shard.  A single global
    dispatch instead forces an all-reduce of the full (E, cap, d) gathered
    tensor (measured 3.6 TB/layer on mixtral train — EXPERIMENTS.md §Perf
    iteration 2).  Capacity is per-group, like per-device capacity in
    production MoE stacks."""
    cfg = ctx.cfg
    e = cfg.moe
    dt = ctx.compute_dtype
    b, s, d = x.shape
    t = b * s
    k = e.top_k
    E = e.num_experts

    # group count = DP shard count (1 on a single host)
    gcount = 1
    if ctx.rules is not None:
        for ax in ("pod", "data"):
            gcount *= ctx.rules.mesh_sizes.get(ax, 1)
    while t % gcount != 0:
        gcount //= 2
    tg = t // gcount
    cap = int(math.ceil(tg * k / E * e.capacity_factor))
    cap = min(max(cap, e.min_capacity), tg * k)

    xt = x.reshape(gcount, tg, d)
    xt = ctx.constrain(xt, "batch", None, "act_embed")
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(dt)).astype(jnp.float32)
    weights, ids = jax.lax.top_k(logits, k)                      # (G, tg, k)
    weights = jax.nn.softmax(weights, axis=-1)

    flat_ids = ids.reshape(gcount, tg * k)                        # expert per slot
    flat_w = weights.reshape(gcount, tg * k)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)         # (G, tg*k, E)
    pos_in_exp = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (G, tg*k)
    keep = pos_in_exp < cap

    token_idx = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (gcount, tg * k)
    )
    # scatter token indices into the (G, E, cap) dispatch table; dropped
    # slots write out-of-bounds and are discarded by mode="drop".  All
    # indexed ops are vmapped over G so they lower to *batched* gathers/
    # scatters, which GSPMD shards on the group dim (a flat 3D advanced
    # index loses that structure and replicates — §Perf iteration 3).
    upd_c = jnp.where(keep, pos_in_exp, cap)
    table = jax.vmap(
        lambda ids, c, tok: jnp.full((E, cap), tg, jnp.int32).at[ids, c].set(tok, mode="drop")
    )(flat_ids, upd_c, token_idx)

    x_pad = jnp.concatenate([xt, jnp.zeros((gcount, 1, d), xt.dtype)], axis=1)
    x_exp = jax.vmap(lambda xp, tbl: xp[tbl])(x_pad, table)  # (G, E, cap, d)
    x_exp = ctx.constrain(x_exp, "batch", "experts", None, "act_embed")

    g = jnp.einsum("gecd,edf->gecf", x_exp, _expert_w(p, "w_gate", dt))
    u = jnp.einsum("gecd,edf->gecf", x_exp, _expert_w(p, "w_up", dt))
    h = jax.nn.silu(g) * u
    h = ctx.constrain(h, "batch", "experts", None, "expert_mlp")
    y_exp = jnp.einsum("gecf,efd->gecd", h, _expert_w(p, "w_down", dt))  # (G, E, cap, d)

    # gather back per slot and combine with routing weights
    slot_e = jnp.where(keep, flat_ids, 0)
    slot_c = jnp.clip(pos_in_exp, 0, cap - 1)
    y_slots = jax.vmap(lambda ye, se, sc: ye[se, sc])(y_exp, slot_e, slot_c)
    y_slots = jnp.where(keep[..., None], y_slots, 0)              # (G, tg*k, d)
    y = jnp.sum(
        (y_slots * flat_w[..., None].astype(dt)).reshape(gcount, tg, k, d), axis=2
    )
    aux = _load_balance_loss(logits.reshape(t, E), ids.reshape(t, k), E)
    return y.reshape(b, s, d), aux


def _load_balance_loss(logits, ids, num_experts):
    """Switch-style auxiliary load-balancing loss."""
    probs = jax.nn.softmax(logits, axis=-1)                       # (t, E)
    density = jnp.mean(
        jax.nn.one_hot(ids[:, 0], num_experts, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(density * density_proxy)
