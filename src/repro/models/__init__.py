from repro.models.config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig  # noqa: F401
from repro.models.layers import Ctx  # noqa: F401
from repro.models.model import (  # noqa: F401
    build_segments,
    cache_specs,
    decode_step,
    forward,
    loss_fn,
    model_specs,
    prefill,
)
