"""Synthetic video stream generator.

Streams are moving-blob scenes with a controllable *motion level* per
segment; the motion level doubles as the ground-truth content difficulty z
(what UA-DETRAC-style traffic scenes vary).  Used by the gate curriculum,
the serving simulator, and the paper-table benchmarks.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VideoConfig:
    height: int = 64
    width: int = 64
    n_blobs: int = 4
    frames_per_segment: int = 8
    seed: int = 0


def generate_stream(cfg: VideoConfig, n_segments: int, motion_profile=None, rng=None):
    """Returns (frames (T, H, W) float32 in [0,1], difficulty (n_segments,)).

    motion_profile: optional (n_segments,) array in [0,1]; default is a
    smooth random walk (scene dynamics drift over time, paper §2).
    """
    rng = rng or np.random.default_rng(cfg.seed)
    n_frames = n_segments * cfg.frames_per_segment + 1
    if motion_profile is None:
        steps = rng.normal(0, 0.15, n_segments)
        motion_profile = np.clip(0.5 + np.cumsum(steps), 0.05, 1.0)
    motion_profile = np.asarray(motion_profile)

    pos = rng.uniform(8, cfg.height - 8, (cfg.n_blobs, 2))
    vel = rng.normal(0, 1.0, (cfg.n_blobs, 2))
    size = rng.uniform(3, 7, cfg.n_blobs)
    yy, xx = np.mgrid[0 : cfg.height, 0 : cfg.width]

    frames = np.zeros((n_frames, cfg.height, cfg.width), np.float32)
    for t in range(n_frames):
        seg = min(t // cfg.frames_per_segment, n_segments - 1)
        speed = 0.3 + 4.0 * motion_profile[seg]
        pos = pos + vel * speed
        # bounce
        for d, lim in ((0, cfg.height), (1, cfg.width)):
            hit = (pos[:, d] < 2) | (pos[:, d] > lim - 2)
            vel[hit, d] *= -1
            pos[:, d] = np.clip(pos[:, d], 2, lim - 2)
        img = np.zeros((cfg.height, cfg.width), np.float32)
        for b in range(cfg.n_blobs):
            img += np.exp(
                -((yy - pos[b, 0]) ** 2 + (xx - pos[b, 1]) ** 2) / (2 * size[b] ** 2)
            )
        noise = rng.normal(0, 0.02, img.shape).astype(np.float32)
        frames[t] = np.clip(img / max(cfg.n_blobs / 2, 1) + noise, 0, 1)
    return frames, motion_profile


def make_task_batch(n_tasks: int, requirement: str = "stable", seed: int = 0):
    """Accuracy requirements per paper §4.1.2: stable U[0.6,0.7],
    fluctuating U[0.5,0.8]."""
    rng = np.random.default_rng(seed)
    if requirement == "stable":
        return rng.uniform(0.6, 0.7, n_tasks).astype(np.float32)
    return rng.uniform(0.5, 0.8, n_tasks).astype(np.float32)
