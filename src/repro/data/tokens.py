"""Synthetic LM token pipeline: zipf-distributed tokens with a repeated-ngram
structure so a ~100M model actually has something learnable (copy heads)."""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int, seed: int = 0,
                 d_model: int = 0, embed_inputs: bool = True, mrope: bool = False):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.d_model = d_model
        self.embed_inputs = embed_inputs
        self.mrope = mrope

    def _sample_tokens(self):
        b, s, v = self.batch, self.seq + 1, self.vocab
        base = self.rng.zipf(1.3, (b, s)).astype(np.int64) % v
        # repeated n-gram structure: second half repeats the first half shifted
        half = s // 2
        base[:, half : half * 2] = base[:, :half]
        return base.astype(np.int32)

    def __iter__(self):
        return self

    def __next__(self):
        toks = self._sample_tokens()
        batch = {}
        pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32), (self.batch, self.seq))
        if self.embed_inputs:
            batch["tokens"] = toks[:, :-1]
        else:
            emb = self.rng.normal(0, 1, (self.batch, self.seq, self.d_model)).astype(np.float32)
            batch["embeddings"] = emb
        batch["labels"] = toks[:, 1:]
        if self.mrope:
            batch["positions"] = np.broadcast_to(pos[:, None, :], (self.batch, 3, self.seq)).copy()
        else:
            batch["positions"] = pos.copy()
        return batch
