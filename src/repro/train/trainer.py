"""Training loop: sharded pjit steps, checkpoint/restart, failure recovery,
optional int8 error-feedback gradient compression."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.models import Ctx, loss_fn, model_specs
from repro.models.config import ModelConfig
from repro.models.params import init_params, shardings as spec_shardings
from repro.sharding.rules import ShardingRules
from repro.train.compression import ef_compress_grads
from repro.train.optimizer import AdamWConfig, AdamWState
from repro.train import optimizer as _opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "results/ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    grad_compression: bool = False
    grad_accum: int = 1   # microbatches per step (activation-memory knob)
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    seed: int = 0


class NodeFailure(RuntimeError):
    """Raised by the failure injector to simulate a node loss mid-run."""


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        mesh=None,
        rules: Optional[ShardingRules] = None,
        failure_injector=None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = rules
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.failure_injector = failure_injector
        self.specs = model_specs(cfg)
        self.step = 0
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        ctx = Ctx(cfg=self.cfg, rules=self.rules, mode="train")
        tcfg = self.tcfg

        def grads_of(params, batch):
            return jax.value_and_grad(
                lambda p: loss_fn(ctx, p, batch), has_aux=True
            )(params)

        def train_step(params, opt_state, err_buf, batch):
            if tcfg.grad_accum > 1:
                # microbatch over the leading batch dim: activation memory
                # scales with batch/grad_accum instead of batch
                def split(x):
                    b = x.shape[0]
                    m = tcfg.grad_accum
                    assert b % m == 0, (b, m)
                    return x.reshape(m, b // m, *x.shape[1:])

                micro = {k: split(v) for k, v in batch.items()}

                def body(carry, mb):
                    acc, loss_acc = carry
                    (loss, _), g = grads_of(params, mb)
                    acc = jax.tree_util.tree_map(jnp.add, acc, g)
                    return (acc, loss_acc + loss), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
                grads = jax.tree_util.tree_map(lambda g: g / tcfg.grad_accum, gsum)
                loss = loss_sum / tcfg.grad_accum
                metrics = {}
            else:
                (loss, metrics), grads = grads_of(params, batch)
            if tcfg.grad_compression:
                grads, err_buf = ef_compress_grads(grads, err_buf)
            new_params, new_opt, om = _opt.update(tcfg.opt, grads, opt_state, params)
            return new_params, new_opt, err_buf, dict(metrics, loss=loss, **om)

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    def init_state(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = init_params(self.specs, rng)
        if self.mesh is not None and self.rules is not None:
            sh = spec_shardings(self.specs, self.mesh, self.rules)
            params = jax.tree_util.tree_map(jax.device_put, params, sh)
        opt_state = _opt.init(params)
        err = (
            jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            if self.tcfg.grad_compression
            else jax.tree_util.tree_map(lambda p: jnp.zeros((), jnp.float32), params)
        )
        return params, opt_state, err

    # ------------------------------------------------------------------
    def maybe_restore(self, state):
        params, opt_state, err = state
        shardings = None
        if self.mesh is not None and self.rules is not None:
            shardings = spec_shardings(self.specs, self.mesh, self.rules)
        tree = {"params": params, "mu": opt_state.mu, "nu": opt_state.nu}
        sh_tree = {"params": shardings, "mu": shardings, "nu": shardings} if shardings else None
        restored, extra = self.ckpt.restore_latest(tree, shardings=sh_tree)
        if restored is None:
            return state
        step = int(extra.get("step", 0))
        self.step = step
        opt_state = AdamWState(
            step=jnp.asarray(step, jnp.int32), mu=restored["mu"], nu=restored["nu"]
        )
        return restored["params"], opt_state, err

    def save(self, state):
        params, opt_state, _ = state
        tree = {"params": params, "mu": opt_state.mu, "nu": opt_state.nu}
        self.ckpt.save(self.step, tree)

    # ------------------------------------------------------------------
    def run(self, data: Iterator[dict], n_steps: Optional[int] = None, state=None):
        """Returns (state, history).  Raises NodeFailure mid-run if injected."""
        if state is None:
            state = self.maybe_restore(self.init_state())
        params, opt_state, err = state
        history = []
        target = self.step + (n_steps or self.tcfg.steps)
        while self.step < target:
            if self.failure_injector is not None:
                self.failure_injector(self.step)
            batch = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, err, metrics = self._step_fn(params, opt_state, err, batch)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == target:
                loss = float(metrics["loss"])
                history.append({"step": self.step, "loss": loss,
                                "grad_norm": float(metrics["grad_norm"])})
            if self.step % self.tcfg.ckpt_every == 0:
                self.save((params, opt_state, err))
        return (params, opt_state, err), history
