"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Optimizer state shards exactly like the parameters (same logical axes), which
under the train rules (FSDP over "data" x TP over "model") gives fully
ZeRO-sharded m/v/master state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), {"grad_norm": gnorm, "lr": lr}
