"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000-node scale).

``compress``/``decompress`` define the wire format (per-tensor absmax int8);
``ef_compress_grads`` wraps a gradient pytree with persistent error-feedback
buffers so the quantization error is re-injected next step (Karimireddy et
al. EF-SGD), keeping convergence intact at 4x lower all-reduce volume.
``compressed_allreduce`` is the shard_map collective used under pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, error_buf):
    """Returns (wire_grads, new_error_buf): quantize (g + e), keep residual."""
    if error_buf is None:
        error_buf = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree_util.tree_map(one, grads, error_buf)
    wire = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return wire, err


def compressed_allreduce(g, axis_name: str):
    """int8-on-the-wire psum for use inside shard_map bodies."""
    q, scale = compress(g)
    # sum of per-shard dequantized grads == dequant of summed int32 payloads
    total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)
    scales = jax.lax.all_gather(scale, axis_name)
    # each shard quantized with its own scale: reconstruct exactly
    qs = jax.lax.all_gather(q, axis_name)
    return jnp.tensordot(scales, qs.astype(jnp.float32), axes=(0, 0))
