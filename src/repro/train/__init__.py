from repro.train.optimizer import AdamWConfig, AdamWState, init as adamw_init, update as adamw_update  # noqa: F401
