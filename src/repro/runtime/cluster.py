"""Cluster runtime simulation: node failures, heartbeats, elastic re-mesh.

On real hardware these events come from the TPU runtime / GKE; here the
injector raises ``NodeFailure`` at scheduled steps and ``elastic_remesh``
rebuilds the largest rectangular mesh from the surviving node count — the
trainer then restores the latest checkpoint with the *new* shardings
(``checkpoint.restore`` device_puts onto the target mesh), which is exactly
the production recovery path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.train.trainer import NodeFailure


@dataclasses.dataclass
class FailureInjector:
    """Raise NodeFailure when the trainer reaches a scheduled step."""
    schedule: Dict[int, str]  # step -> failure description
    fired: set = dataclasses.field(default_factory=set)

    def __call__(self, step: int):
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            raise NodeFailure(f"step {step}: {self.schedule[step]}")


def elastic_remesh(n_devices: Optional[int] = None, *, min_model: int = 1,
                   prefer: str = "model"):
    """Largest (data, model) mesh from the surviving devices.

    ``prefer="model"`` (default, trainer recovery) keeps the model axis as
    large as possible — TP degree is bounded by what the weights were
    sharded for — and puts the remainder on data.  ``prefer="data"``
    (serving recovery) puts every surviving device on the data axis: serve
    streams shard along data only, so a survivor mesh of shape (n, 1) keeps
    all of them routing.
    """
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    if n <= 0:
        raise ValueError(
            f"elastic_remesh needs at least one surviving device, got "
            f"n_devices={n_devices!r}")
    if prefer not in ("model", "data"):
        raise ValueError(f"prefer must be 'model' or 'data', got {prefer!r}")
    n = min(n, len(devs))
    if prefer == "data":
        model = max(min_model, 1)
        if n % model != 0:
            raise ValueError(
                f"{n} surviving devices not divisible by min_model={model}")
    else:
        model = 1
        for cand in (16, 8, 4, 2, 1):
            if cand <= n and n % cand == 0 and cand >= min_model:
                model = cand
                break
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"), devices=devs[:n])


class ClusterSim:
    """Tracks node liveness via heartbeats; feeds the elastic controller."""

    def __init__(self, n_nodes: int, heartbeat_timeout: float = 3.0):
        self.n_nodes = n_nodes
        self.timeout = heartbeat_timeout
        self.last_seen = {i: 0.0 for i in range(n_nodes)}
        self.dead: set[int] = set()
        self.clock = 0.0

    def tick(self, dt: float = 1.0, heartbeats: Optional[set] = None):
        self.clock += dt
        for i in (heartbeats if heartbeats is not None else set(range(self.n_nodes))):
            if i not in self.dead:
                self.last_seen[i] = self.clock
        newly_dead = {
            i for i in range(self.n_nodes)
            if i not in self.dead and self.clock - self.last_seen[i] > self.timeout
        }
        self.dead |= newly_dead
        return newly_dead

    def kill(self, node: int):
        self.dead.add(node)

    @property
    def alive(self) -> int:
        return self.n_nodes - len(self.dead)
