from repro.runtime.cluster import ClusterSim, FailureInjector, elastic_remesh  # noqa: F401
from repro.runtime.straggler import hedged_dispatch, p99  # noqa: F401
