"""Straggler mitigation: hedged dispatch for serving pools.

A segment is sent to the least-loaded server; if its latency estimate
exceeds the hedge deadline (q-th percentile of recent completions), a backup
copy is dispatched to the next pool and the first finisher wins — the
standard tail-at-scale recipe, applied at the R2E-VID scheduler level.
"""
from __future__ import annotations

import numpy as np


def p99(samples):
    return float(np.percentile(np.asarray(samples), 99))


def hedged_dispatch(latencies, *, hedge_quantile: float = 0.9, hedge_cost: float = 0.05,
                    rng=None):
    """latencies: (n_tasks, n_replicas) latency draws per task per replica.

    Returns realized per-task latency with hedging: the primary replica is
    used unless its draw exceeds the hedge deadline, in which case the task
    also runs on a backup and takes min(primary, deadline + backup).
    """
    lat = np.asarray(latencies, np.float64)
    primary = lat[:, 0]
    deadline = np.quantile(primary, hedge_quantile)
    if lat.shape[1] < 2:
        return primary
    backup = lat[:, 1] + deadline + hedge_cost
    hedged = np.where(primary > deadline, np.minimum(primary, backup), primary)
    return hedged
