"""Straggler mitigation: hedged dispatch for serving pools.

A segment is sent to the least-loaded server; if its latency estimate
exceeds the hedge deadline (q-th percentile of recent completions), a backup
copy is dispatched to the next pool and the first finisher wins — the
standard tail-at-scale recipe, applied at the R2E-VID scheduler level.

The jnp ports (``hedged_dispatch_jnp`` / ``p99_jnp``) are the jit- and
scan-compatible forms fused into ``realize_rounds`` by the scenario engine;
the numpy originals stay as the parity oracles.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def p99(samples):
    return float(np.percentile(np.asarray(samples), 99))


def p99_jnp(samples):
    """jnp port of :func:`p99` — traceable, returns a 0-d array."""
    return jnp.quantile(jnp.asarray(samples, jnp.float32).ravel(), 0.99)


def hedged_dispatch(latencies, *, hedge_quantile: float = 0.9, hedge_cost: float = 0.05,
                    rng=None):
    """latencies: (n_tasks, n_replicas) latency draws per task per replica.

    Returns realized per-task latency with hedging: the primary replica is
    used unless its draw exceeds the hedge deadline, in which case the task
    also runs on a backup and takes min(primary, deadline + backup).
    """
    lat = np.asarray(latencies, np.float64)
    primary = lat[:, 0]
    deadline = np.quantile(primary, hedge_quantile)
    if lat.shape[1] < 2:
        return primary
    backup = lat[:, 1] + deadline + hedge_cost
    hedged = np.where(primary > deadline, np.minimum(primary, backup), primary)
    return hedged


def hedged_dispatch_jnp(latencies, *, hedge_quantile: float = 0.9,
                        hedge_cost: float = 0.05):
    """jnp port of :func:`hedged_dispatch` (same semantics, same interpolated
    quantile), traceable under ``jit``/``vmap``/``scan``.

    latencies: (..., n_tasks, n_replicas); the hedge deadline is the
    ``hedge_quantile``-th quantile of the primary draws along the task axis
    (per leading batch element).  Single-replica pools return the primary
    draws unchanged, exactly like the numpy oracle.
    """
    lat = jnp.asarray(latencies, jnp.float32)
    primary = lat[..., 0]
    if lat.shape[-1] < 2:
        return primary
    deadline = jnp.quantile(primary, hedge_quantile, axis=-1, keepdims=True)
    backup = lat[..., 1] + deadline + hedge_cost
    return jnp.where(primary > deadline, jnp.minimum(primary, backup), primary)
