"""Per-kernel micro-benchmarks: interpret-mode correctness-path timing on CPU
plus analytic TPU-roofline derived throughput (the real number a TPU would
see, from the kernel's HBM traffic model)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_flash_attention():
    from repro.kernels.flash_attention.ref import attention_ref
    b, h, kv, s, d = 1, 8, 2, 1024, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b_, c: attention_ref(a, b_, c)), q, k, v)
    flops = 4 * b * h * s * s * d * 0.5  # causal
    tpu_us = flops / PEAK_FLOPS * 1e6
    return us, f"tpu_roofline_us={tpu_us:.1f} flops={flops:.2e}"


def bench_decode_attention():
    from repro.kernels.decode_attention.ref import decode_attention_ref
    b, h, kv, s, d = 8, 32, 8, 32768, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
    kc = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    vc = jax.random.normal(key, (b, kv, s, d), jnp.bfloat16)
    ln = jnp.full((b,), s, jnp.int32)
    us = _time(jax.jit(decode_attention_ref), q, kc, vc, ln)
    bytes_ = kc.size * 2 * 2  # stream k+v once
    tpu_us = bytes_ / HBM_BW * 1e6
    return us, f"tpu_roofline_us={tpu_us:.1f} cache_bytes={bytes_:.2e}"


def bench_mamba_scan():
    from repro.kernels.mamba_scan.kernel import selective_scan
    b, s, di, n = 2, 512, 256, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, di)) * 0.5)
    B = jax.random.normal(key, (b, s, n))
    C = jax.random.normal(key, (b, s, n))
    A = -jnp.exp(jax.random.normal(key, (di, n)) * 0.2)
    D = jnp.ones((di,))
    fn = jax.jit(lambda *a: selective_scan(*a, block_t=128, block_d=128, interpret=True))
    us = _time(fn, x, dt, B, C, A, D, iters=1)
    bytes_ = (x.size * 2 + B.size * 2) * 4 + x.size * 4
    tpu_us = bytes_ / HBM_BW * 1e6
    return us, f"tpu_roofline_us={tpu_us:.1f} (interpret-mode timing)"


def bench_rglru():
    from repro.kernels.rglru.kernel import rglru_scan
    b, s, w = 2, 512, 256
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, w))
    r = jax.nn.sigmoid(jax.random.normal(key, (b, s, w)))
    i = jax.nn.sigmoid(jax.random.normal(key, (b, s, w)))
    la = -jax.nn.softplus(jax.random.normal(key, (w,)))
    fn = jax.jit(lambda *a: rglru_scan(*a, block_t=128, block_w=128, interpret=True))
    us = _time(fn, x, r, i, la, iters=1)
    bytes_ = x.size * 3 * 4 + x.size * 4
    tpu_us = bytes_ / HBM_BW * 1e6
    return us, f"tpu_roofline_us={tpu_us:.1f} (interpret-mode timing)"


def bench_temporal_gate():
    from repro.kernels.temporal_gate.ref import gate_cell_ref
    from repro.core.gating import GateConfig, gate_specs
    from repro.models.params import init_params
    b, d, m = 4096, 35, 32
    gcfg = GateConfig(d_feature=d, d_hidden=m)
    p = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    dx = jax.random.normal(key, (b, d))
    h = jax.random.normal(key, (b, m)) * 0.1
    vol = jax.random.uniform(key, (b,))
    us = _time(jax.jit(gate_cell_ref), dx, h, vol, p)
    flops = 2 * b * (3 * d * m + 3 * m * m + m)
    tpu_us = max(flops / PEAK_FLOPS, (dx.size + h.size) * 4 * 3 / HBM_BW) * 1e6
    return us, f"tpu_roofline_us={tpu_us:.2f} streams={b}"


def bench_robust_solver():
    import numpy as np
    from repro.core.cost_model import SystemConfig
    from repro.core.robust import RobustProblem, solve_ccg
    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    z = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 512), jnp.float32)
    aq = jnp.asarray(np.random.default_rng(1).uniform(0.5, 0.8, 512), jnp.float32)
    fn = jax.jit(lambda z_, a_: solve_ccg(prob, z_, a_)["o_up"])
    us = _time(fn, z, aq)
    return us, f"tasks=512 ({us/512:.1f}us/task CCG)"


ALL = {
    "kernel/flash_attention": bench_flash_attention,
    "kernel/decode_attention": bench_decode_attention,
    "kernel/mamba_scan": bench_mamba_scan,
    "kernel/rglru": bench_rglru,
    "kernel/temporal_gate": bench_temporal_gate,
    "core/robust_ccg": bench_robust_solver,
}
