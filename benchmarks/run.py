"""Benchmark harness: one entry per paper table/figure + kernel micro-benches.

Prints ``name,us_per_call,derived`` CSV lines (per the repo contract), then
the paper-artifact tables.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def main() -> None:
    from repro.core.cost_model import SystemConfig
    from benchmarks import kernel_bench, paper_tables, roofline_table

    sys_cfg = SystemConfig()
    print("name,us_per_call,derived")

    # --- kernel + solver micro-benchmarks ---------------------------------
    for name, fn in kernel_bench.ALL.items():
        us, derived = fn()
        print(f"{name},{us:.1f},{derived}")

    # --- paper artifacts ---------------------------------------------------
    artifacts = {
        "paper/fig5_accuracy_cost": paper_tables.fig5_accuracy_cost_tradeoff,
        "paper/table1_accuracy": paper_tables.table1_accuracy,
        "paper/table2_segmentation": paper_tables.table2_segmentation,
        "paper/table3_success": paper_tables.table3_success_rates,
        "paper/figs678_scaling": paper_tables.figs678_task_scaling,
        "paper/fig9_dynamic_bw": paper_tables.fig9_dynamic_bandwidth,
        "paper/fig10_ablation": paper_tables.fig10_ablation,
    }
    results = {}
    for name, fn in artifacts.items():
        t0 = time.perf_counter()
        rows = fn(sys_cfg)
        us = (time.perf_counter() - t0) * 1e6
        results[name] = rows
        print(f"{name},{us:.0f},rows={len(rows)}")

    # --- derived headline numbers (paper-claim validation) ----------------
    fig9 = results["paper/fig9_dynamic_bw"]
    by = {}
    for ds, fl, name, cost in fig9:
        by.setdefault((ds, fl), {})[name] = cost
    reds_a2, reds_jcab = [], []
    for key, d in by.items():
        if key[1] >= 0.2:
            reds_a2.append(1 - d["R2E-VID"] / d["A2"])
            reds_jcab.append(1 - d["R2E-VID"] / max(d["JCAB"], 1e-9))
    print(f"claim/cost_reduction_vs_cloud_only,0,{np.mean(reds_a2)*100:.1f}% (paper: up to 60%)")
    print(f"claim/cost_reduction_vs_jcab,0,{np.mean(reds_jcab)*100:.1f}% (paper: 35-45%)")

    t3 = results["paper/table3_success"]
    ours = [r[3] for r in t3 if r[2] == "R2E-VID"]
    print(f"claim/success_rate_ours_min,0,{min(ours)*100:.1f}% (paper: >=91%)")

    abl = results["paper/fig10_ablation"]
    print("\n# --- Fig 10 ablation (accuracy, cost, success) ---")
    for vname, acc, cost, succ in abl:
        print(f"# {vname:12s} acc={acc:.3f} cost={cost:.3f} success={succ:.3f}")

    print("\n# --- Table 2 segmentation proxies (MIoU / MPA) ---")
    for bw, name, miou, mpa in results["paper/table2_segmentation"]:
        print(f"# {bw:12s} {name:8s} MIoU={miou:5.2f} MPA={mpa:5.2f}")

    print("\n# --- Table 3 success rates ---")
    for ds, req, name, s in t3:
        print(f"# {ds:10s} {req:12s} {name:8s} {s*100:5.1f}%")

    # --- roofline table from dry-run artifacts ----------------------------
    print("\n# --- Roofline: paper-faithful baseline (results/dryrun) ---")
    roofline_table.print_table("results/dryrun")
    import os
    if os.path.isdir("results/dryrun_opt"):
        print("\n# --- Roofline: optimized / shipped code (results/dryrun_opt) ---")
        roofline_table.print_table("results/dryrun_opt")


if __name__ == "__main__":
    main()
