"""Scenario robustness suite: every registered policy through every named
adverse scenario, with checked-in goldens (the Table-2 generalization).

  PYTHONPATH=src python benchmarks/scenario_suite.py                # print
  PYTHONPATH=src python benchmarks/scenario_suite.py --write        # refresh
  PYTHONPATH=src python benchmarks/scenario_suite.py --check        # gate
  PYTHONPATH=src python benchmarks/scenario_suite.py \\
      --policies r2evid,a2_cloud_only --scenarios edge_outage,none --check

Each cell is ONE compiled ``ServeSession.run`` scan over the degraded
stream (``repro.serving.scenarios.run_scenario``); the realization is
deterministic (no observation noise), so the goldens are reproducible to
float32 fidelity from the (sim seed, scenario seed, M, R) tuple alone.

``--write`` stores every cell's scalars in ``SCENARIO_GOLDENS.json`` at the
repo root; ``--check`` recomputes the requested cells and fails the process
if any metric drifts beyond ``--tol`` (relative) from its golden — the CI
robustness gate.  A cell missing from the goldens fails ``--check`` too:
new policies / scenarios must land with refreshed goldens.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent
GOLDEN_PATH = ROOT / "SCENARIO_GOLDENS.json"
TOL = 1e-4

_METRIC_ORDER = ("cost", "delay", "accuracy", "sla_violation_rate",
                 "sla_cost", "cloud_frac", "recovery_rounds")


def run_cells(policies, scenarios, streams: int, rounds: int):
    from repro.serving.scenarios import run_scenario

    rows = {}
    for scen in scenarios:
        for pol in policies:
            t0 = time.perf_counter()
            rows[f"{pol}@{scen}"] = run_scenario(
                pol, scen, streams=streams, rounds=rounds)
            dt = time.perf_counter() - t0
            print(f"ran {pol}@{scen} in {dt:.1f}s", flush=True)
    return rows


def check(rows, tol: float) -> int:
    if not GOLDEN_PATH.exists():
        print(f"check: {GOLDEN_PATH} missing — run with --write first")
        return len(rows)
    gold = json.loads(GOLDEN_PATH.read_text())
    bad = 0
    for key, scalars in rows.items():
        ref = gold["rows"].get(key)
        if ref is None:
            print(f"check: {key} has NO golden row — refresh with --write")
            bad += 1
            continue
        for metric in _METRIC_ORDER:
            got, want = scalars[metric], ref[metric]
            denom = max(abs(want), 1e-9)
            drift = abs(got - want) / denom
            if drift > tol and abs(got - want) > tol:
                print(f"check: {key}:{metric} {got:.6f} vs golden "
                      f"{want:.6f} (drift {drift:.2e}) DRIFT")
                bad += 1
    if not bad:
        print(f"check: {len(rows)} cells within tol={tol:g} of goldens")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--policies", default="",
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated scenario names (default: the "
                         "named SUITE plus the benign 'none' control)")
    ap.add_argument("--write", action="store_true",
                    help=f"refresh {GOLDEN_PATH.name} with this run")
    ap.add_argument("--check", action="store_true",
                    help="fail if any cell drifts from its golden")
    ap.add_argument("--tol", type=float, default=TOL)
    args = ap.parse_args()

    from repro.serving.policy import POLICIES
    from repro.serving.scenarios import SUITE

    policies = ([p for p in args.policies.split(",") if p]
                or sorted(POLICIES))
    scenarios = ([s for s in args.scenarios.split(",") if s]
                 or list(SUITE) + ["none"])

    rows = run_cells(policies, scenarios, args.streams, args.rounds)

    print("cell," + ",".join(_METRIC_ORDER))
    for key, scalars in rows.items():
        print(key + "," + ",".join(f"{scalars[m]:.6f}" for m in _METRIC_ORDER))

    n_bad = check(rows, args.tol) if args.check else 0

    if args.write:
        if GOLDEN_PATH.exists():
            out = json.loads(GOLDEN_PATH.read_text())
            if (out["config"]["streams"] != args.streams
                    or out["config"]["rounds"] != args.rounds):
                sys.exit(f"refusing to merge {args.streams}x{args.rounds} "
                         f"cells into goldens at "
                         f"{out['config']['streams']}x"
                         f"{out['config']['rounds']} — delete "
                         f"{GOLDEN_PATH.name} to restart")
            out["rows"].update(rows)
        else:
            out = {"config": {"streams": args.streams, "rounds": args.rounds,
                              "seed": 11, "scenario_seed": 0},
                   "rows": rows}
        out["rows"] = {k: {m: round(v[m], 6) for m in _METRIC_ORDER}
                       for k, v in sorted(out["rows"].items())}
        GOLDEN_PATH.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {GOLDEN_PATH}")

    if n_bad:
        sys.exit(f"{n_bad} golden cell(s) drifted beyond tol={args.tol:g}")


if __name__ == "__main__":
    main()
