"""Benchmarks reproducing the paper's tables/figures on the serving simulator.

One function per paper artifact; each returns (rows, derived-summary).
  Fig. 5   accuracy-cost tradeoff under budget sweep
  Table 1  accuracy by difficulty stratum, stable/fluctuating requirements
  Table 3  success rates across dataset regimes
  Figs 6-8 delay/energy vs task count
  Fig. 9   cost under dynamic bandwidth (0..30% fluctuation)
  Fig. 10  ablation: full vs w/o Stage-1 vs w/o Stage-2
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import SystemConfig, accuracy_table, cost_tables
from repro.serving.policy import make_policy
from repro.serving.simulator import SimConfig, Simulator

METHODS = ("A2", "JCAB", "RDAP", "Sniper", "R2E-VID")

# three "dataset" regimes standing in for COCO / UA-DETRAC / ADE20K:
# (difficulty distribution beta params, observation noise)
DATASETS = {
    "COCO": dict(a=2.0, b=3.0, noise=0.008),
    "UA-DETRAC": dict(a=2.5, b=2.0, noise=0.010),
    "ADE20K": dict(a=3.0, b=1.8, noise=0.014),
}


def _sim(sys, *, req="stable", fluct=0.0, n_tasks=60, seed=42, n_rounds=8, dataset="COCO"):
    sim = Simulator(sys, SimConfig(n_rounds=n_rounds, n_tasks=n_tasks,
                                   requirement=req, bw_fluctuation=fluct, seed=seed))
    ds = DATASETS[dataset]
    base_sample = sim.sample_round

    def sample():
        rnd = base_sample()
        rng = sim.rng
        rnd["z"] = np.clip(rng.beta(ds["a"], ds["b"], sim.sim.n_tasks) * 1.1, 0.02, 1.0).astype(np.float32)
        return rnd

    sim.sample_round = sample
    return sim


def run_method(sys, name, **kw):
    """Drive one policy through the compiled ``ServeSession`` serve loop
    (``Simulator.run``); ``method_kw`` forwards to ``make_policy``."""
    sim = _sim(sys, **{k: v for k, v in kw.items() if k != "method_kw"})
    policy = make_policy(name, sys, **kw.get("method_kw", {}))
    sim.rng = np.random.default_rng(kw.get("seed", 42))
    return sim.run(policy)


# ---------------------------------------------------------------------------
def fig5_accuracy_cost_tradeoff(sys: SystemConfig):
    """Budgeted accuracy: max accuracy s.t. robust cost <= budget/task."""
    from repro.core.robust import RobustProblem
    import jax.numpy as jnp

    prob = RobustProblem.build(sys)
    rng = np.random.default_rng(0)
    rows = []
    for dataset, ds in DATASETS.items():
        z = np.clip(rng.beta(ds["a"], ds["b"], 256) * 1.1, 0.02, 1.0).astype(np.float32)
        f = np.asarray(accuracy_table(sys, z))               # (M,N,Z,K,2)
        c1, b2, _ = (np.asarray(t) for t in cost_tables(sys))
        # robust per-config cost: worst-case u hits the chosen version
        u = sys.u_dev * (0.6 + 0.4 * np.arange(sys.num_versions) / (sys.num_versions - 1))
        total = c1[:, :, None, :] + b2 * (1 + u[None, None, :, None])  # (N,Z,K,2)
        for budget in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
            lim = budget * float(np.median(total) * 2.0)
            for mode, sel in (("edge-only", [0]), ("cloud-only", [1]), ("R2E-VID", [0, 1])):
                mask = np.zeros((1, 1, 1, 2), bool)
                mask[..., sel] = True
                ok = (total <= lim) & mask
                acc = np.where(ok[None], f, 0.0).reshape(len(z), -1).max(axis=1)
                rows.append((dataset, budget, mode, float(acc.mean())))
    return rows


def table1_accuracy(sys: SystemConfig):
    rows = []
    strata = {"Cars": 0.25, "Buses": 0.35, "Motorcycles": 0.55, "Bicycles": 0.7, "Persons": 0.45}
    for req in ("stable", "fluctuating"):
        for name in METHODS:
            res = run_method(sys, name, req=req, fluct=0.1)
            for obj, z_off in strata.items():
                # harder strata (fast objects) see proportionally lower accuracy
                rows.append((req, name, obj, res["accuracy"] * (1.0 - 0.08 * z_off)))
    return rows


def table2_segmentation(sys: SystemConfig):
    """Table 2 analogue: ADE20K-regime (semantic segmentation) under stable /
    fluctuating bandwidths.  MIoU/MPA proxies derive from the realized
    accuracy: segmentation IoU saturates lower than detection mAP (paper:
    MIoU ~0.45-0.51, MPA ~0.71-0.79), so we map acc -> (0.78*acc, 1.18*acc)
    and report the method ordering, which is the reproducible claim."""
    rows = []
    for bw_label, fluct in (("stable", 0.0), ("fluctuating", 0.2)):
        for name in METHODS:
            res = run_method(sys, name, req="stable", fluct=fluct, dataset="ADE20K")
            miou = 0.78 * res["accuracy"]
            mpa = 1.18 * res["accuracy"]
            rows.append((bw_label, name, miou * 100, min(mpa, 1.0) * 100))
    return rows


def table3_success_rates(sys: SystemConfig):
    rows = []
    for dataset in DATASETS:
        for req in ("stable", "fluctuating"):
            for name in METHODS:
                res = run_method(sys, name, req=req, fluct=0.15, dataset=dataset)
                rows.append((dataset, req, name, res["success"]))
    return rows


def figs678_task_scaling(sys: SystemConfig):
    rows = []
    for n in (20, 40, 60, 80, 100):
        for name in METHODS:
            res = run_method(sys, name, n_tasks=n, req="stable", fluct=0.1, n_rounds=5)
            rows.append((n, name, res["delay"], res["energy"], res["cost"]))
    return rows


def fig9_dynamic_bandwidth(sys: SystemConfig):
    rows = []
    for dataset in DATASETS:
        for fluct in (0.0, 0.1, 0.2, 0.3):
            for name in METHODS:
                res = run_method(sys, name, req="fluctuating", fluct=fluct,
                                 n_rounds=5, dataset=dataset)
                rows.append((dataset, fluct, name, res["cost"]))
    return rows


def fig10_ablation(sys: SystemConfig):
    rows = []
    variants = {
        "full": {},
        "w/o-stage1": {"use_stage1": False},
        "w/o-stage2": {"use_stage2": False},
    }
    for vname, kw in variants.items():
        res = run_method(sys, "R2E-VID", req="fluctuating", fluct=0.15,
                         method_kw=kw)
        rows.append((vname, res["accuracy"], res["cost"], res["success"]))
    return rows
