"""Dispatch executor benchmark: continuous batching vs the serial oracle.

  PYTHONPATH=src python benchmarks/dispatch_bench.py [--streams 16] [--waves 3]
  PYTHONPATH=src python benchmarks/dispatch_bench.py --json   # + BENCH_dispatch.json
  PYTHONPATH=src python benchmarks/dispatch_bench.py --check  # speedup gate

Prints ``name,us_per_call,derived`` CSV lines (the repo benchmark contract):

  dispatch/serial@{mix}     — per-request latency of the serial oracle
                              (grouped ``serve_segment`` calls, no queueing,
                              no cross-batch decode merge) on a mixed-
                              fidelity staggered-arrival workload, with the
                              derived end-to-end tokens/s
  dispatch/continuous@{mix} — the same workload through the continuous-
                              batching executor (bucketed prefills + token-
                              level slab decode, waves submitted mid-flight),
                              derived tokens/s and the speedup over serial
  dispatch/tier{t}@{mix}    — the executor's measured per-tier tail: p50
                              request sojourn as the latency column, p99 and
                              tier tokens/s in the derived field

Mixes are edge/cloud arrival splits (the routed tier of each request):
``balanced`` (50/50), ``edge_heavy`` (75/25), ``cloud_heavy`` (25/75).

With ``--json`` the rows are written to ``BENCH_dispatch.json`` and a
one-line snapshot appended to ``BENCH_history.jsonl``.  With ``--check``
the run becomes the CI gate: continuous batching must not be slower than
the serial oracle (tokens/s ratio >= ``MIN_SPEEDUP``) at any mix.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

import jax
import numpy as np

# --check fails any mix whose continuous/serial tokens-per-second ratio is
# below this (1.0 = "not slower"; headroom left for noisy shared runners is
# intentionally NOT granted — continuous batching that loses to a serial
# loop is a scheduling bug, not noise)
MIN_SPEEDUP = 1.0

MIXES = {"balanced": 0.5, "edge_heavy": 0.75, "cloud_heavy": 0.25}


def make_wave(pools, wave: int, n: int, edge_frac: float, seed: int,
              decode_tokens: int):
    """One arrival wave: mixed fidelity (r in {0,1,2} -> 16/32/48-token
    prompts), tiers split by ``edge_frac``."""
    from repro.serving.dispatch import Request

    rng = np.random.default_rng(seed * 1000 + wave)
    reqs = []
    for i in range(n):
        stream = wave * n + i
        tier = 0 if rng.uniform() < edge_frac else 1
        n_tok = 16 * (1 + int(rng.integers(0, 3)))
        vocab = pools[tier].cfg.vocab_size
        toks = ((stream * 131 + np.arange(n_tok)) % vocab).astype(np.int32)
        reqs.append(Request(stream=stream, tier=tier, tokens=toks,
                            decode_tokens=decode_tokens))
    return reqs


def run_serial(pools, waves):
    """The serial baseline: each wave's requests served back-to-back through
    grouped ``serve_segment`` calls (a wave cannot overlap the previous one
    — the serial path has no queue to hold arrivals)."""
    from repro.serving.dispatch import serve_serial_oracle
    import dataclasses

    t0 = time.perf_counter()
    for wave in waves:
        serve_serial_oracle(pools, [dataclasses.replace(r) for r in wave])
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) + r.decode_tokens for w in waves for r in w)
    return dt, toks


def run_continuous(ex, waves, stagger_steps: int):
    """Waves submitted mid-flight: each wave lands after ``stagger_steps``
    scheduling iterations of the previous one — the staggered-arrival
    pattern the executor's admit/decode interleave is built for."""
    import dataclasses

    t0 = time.perf_counter()
    for wave in waves:
        ex.submit([dataclasses.replace(r) for r in wave])
        for _ in range(stagger_steps):
            ex.step()
    ex.drain()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) + r.decode_tokens for w in waves for r in w)
    return dt, toks


def bench_dispatch(streams: int, waves: int, decode_tokens: int,
                   stagger_steps: int, n_slots: int):
    from repro.configs import get_smoke_config
    from repro.serving.dispatch import DispatchExecutor
    from repro.serving.pools import make_tier_pools

    pools = make_tier_pools(get_smoke_config("qwen1.5-0.5b"),
                            get_smoke_config("qwen3-8b"))
    ex = DispatchExecutor(pools, n_slots=n_slots)

    rows, speedups = [], {}
    for mix, edge_frac in MIXES.items():
        wv = [make_wave(pools, w, streams, edge_frac, seed=7,
                        decode_tokens=decode_tokens)
              for w in range(waves)]
        n_req = streams * waves
        # untimed pass compiles every (bucket, length) prefill shape and the
        # slab decode for BOTH paths, so the timed pass measures scheduling
        run_serial(pools, wv)
        run_continuous(ex, wv, stagger_steps)

        ser_dt, toks = run_serial(pools, wv)
        ex.reset_measurements()
        mark = {t: len(e.completions) for t, e in ex.execs.items()}
        con_dt, _ = run_continuous(ex, wv, stagger_steps)

        ser_tps, con_tps = toks / ser_dt, toks / con_dt
        speedup = con_tps / ser_tps
        speedups[mix] = speedup
        rows.append((f"dispatch/serial@{mix}", ser_dt / n_req * 1e6,
                     f"tokens_per_s={ser_tps:.0f}"))
        rows.append((f"dispatch/continuous@{mix}", con_dt / n_req * 1e6,
                     f"tokens_per_s={con_tps:.0f};speedup={speedup:.2f}x"))
        for t in sorted(ex.execs):
            st = ex._tier_stats(t, since=mark[t])
            if st["requests"] == 0:
                continue
            rows.append((
                f"dispatch/tier{t}@{mix}", st["p50_s"] * 1e6,
                f"p99_us={st['p99_s'] * 1e6:.0f};"
                f"tokens_per_s={st['tokens_per_s']:.0f}"))
    return rows, speedups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=16,
                    help="requests per arrival wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--decode-tokens", type=int, default=16,
                    help="decode depth per request (token-level batching "
                         "wins grow with decode share)")
    ap.add_argument("--stagger-steps", type=int, default=4,
                    help="scheduling steps between wave arrivals")
    ap.add_argument("--n-slots", type=int, default=8,
                    help="cache-slot slab size per tier (right-size to the "
                         "per-tier arrival rate: idle slots are overcompute)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_dispatch.json next to the repo root")
    ap.add_argument("--check", action="store_true",
                    help="fail unless continuous tokens/s >= %.2fx serial "
                         "at every mix" % MIN_SPEEDUP)
    args = ap.parse_args()

    rows, speedups = bench_dispatch(args.streams, args.waves,
                                    args.decode_tokens, args.stagger_steps,
                                    args.n_slots)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    n_bad = 0
    if args.check:
        for mix, s in speedups.items():
            if s < MIN_SPEEDUP:
                print(f"CHECK FAIL: {mix} continuous/serial speedup "
                      f"{s:.2f}x < {MIN_SPEEDUP:.2f}x")
                n_bad += 1
        if not n_bad:
            print(f"check ok: min speedup "
                  f"{min(speedups.values()):.2f}x >= {MIN_SPEEDUP:.2f}x")

    if args.json:
        out = {
            "config": {"streams": args.streams, "waves": args.waves,
                       "decode_tokens": args.decode_tokens,
                       "stagger_steps": args.stagger_steps,
                       "n_slots": args.n_slots,
                       "backend": jax.default_backend()},
            "benchmarks": [
                {"name": name, "us_per_call": round(us, 2), "derived": derived}
                for name, us, derived in rows
            ],
            "speedups": {m: round(s, 3) for m, s in speedups.items()},
        }
        root = pathlib.Path(__file__).resolve().parent.parent
        path = root / "BENCH_dispatch.json"
        path.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {path}")

        headline = {f"dispatch/speedup@{m}": round(s, 3)
                    for m, s in speedups.items()}
        try:
            commit = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"], cwd=root,
                capture_output=True, text=True, check=True).stdout.strip()
        except (OSError, subprocess.CalledProcessError):
            commit = "unknown"
        hist = root / "BENCH_history.jsonl"
        line = {"commit": commit, "bench": "dispatch",
                "date": time.strftime("%Y-%m-%d"),
                "backend": jax.default_backend(), "headline": headline}
        with hist.open("a") as f:
            f.write(json.dumps(line) + "\n")
        print(f"appended {hist}")

    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
