"""Router engine benchmark: steady-state ``route_step`` latency + simulator
realization throughput.

  PYTHONPATH=src python benchmarks/router_bench.py [--streams 64] [--steps 50]

Prints ``name,us_per_call,derived`` CSV lines (the repo benchmark contract):

  router/route_step      — steady-state latency of one jit-compiled streaming
                           step (gate advance + CCG + C6 repair) and the
                           derived segments/sec
  router/route_windowed  — the stateless windowed ``route`` on the same load
                           (re-scans the whole feature window each call)
  sim/realize_vectorized — vectorized ``Simulator.realize``
  sim/realize_reference  — original per-task loop, plus max metric deviation
                           between the two on a fixed seed
  sim/realize_batch_per_round — amortized per-round cost when whole rounds
                           are realized in one vmapped batch
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, iters: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_route_step(streams: int, steps: int, window: int = 8):
    from repro.core.cost_model import SystemConfig
    from repro.core.features import feature_dim
    from repro.core.gating import GateConfig, gate_specs
    from repro.core.robust import RobustProblem
    from repro.core.router import RouterEngine, route
    from repro.models.params import init_params

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.uniform(0, 1, streams), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, streams), jnp.float32)
    dx = jnp.asarray(rng.normal(size=(streams, feature_dim())), jnp.float32)

    engine = RouterEngine(prob, gcfg, gparams, n_streams=streams)

    def step():
        sol = engine.step(dx, z, aq)
        jax.block_until_ready(sol["route"])

    us_step = _timeit(step, steps)
    seg_per_s = streams / (us_step / 1e6)

    dx_win = jnp.asarray(rng.normal(size=(streams, window, feature_dim())), jnp.float32)

    def windowed():
        sol = route(prob, gcfg, gparams, dx_win, z, aq)
        jax.block_until_ready(sol["route"])

    us_win = _timeit(windowed, max(steps // 4, 3))
    return [
        ("router/route_step", us_step, f"segments_per_s={seg_per_s:.0f}"),
        ("router/route_windowed", us_win, f"window={window}"),
    ]


def bench_realize(n_tasks: int, iters: int = 20):
    from repro.core.cost_model import SystemConfig
    from repro.serving.baselines import make_method
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    sim = Simulator(sys_, SimConfig(n_tasks=n_tasks, seed=3, bw_fluctuation=0.2))
    rnd = sim.sample_round()
    cfg = make_method("JCAB", sys_)(rnd, {})

    us_vec = _timeit(lambda: sim.realize(rnd, cfg), iters)
    us_ref = _timeit(lambda: sim.realize_reference(rnd, cfg), iters)

    n_batch = 16
    rnds = [rnd] * n_batch
    cfgs = [cfg] * n_batch
    us_batch = _timeit(lambda: sim.realize_batch(rnds, cfgs), max(iters // 4, 3))
    us_batch_per_round = us_batch / n_batch

    # parity on a fixed seed: identical observation noise for both paths
    noise = np.zeros(n_tasks)
    met_v = sim._realize_deterministic(rnd, cfg)
    met_r = sim.realize_reference(rnd, cfg, noise=noise)
    dev = max(
        float(np.abs(met_v[k] - met_r[k]).max())
        for k in ("delay", "energy", "cost", "accuracy")
    )
    return [
        ("sim/realize_vectorized", us_vec, f"n_tasks={n_tasks}"),
        ("sim/realize_reference", us_ref,
         f"speedup={us_ref / max(us_vec, 1e-9):.1f}x,max_dev={dev:.2e}"),
        ("sim/realize_batch_per_round", us_batch_per_round,
         f"rounds={n_batch},speedup_vs_loop={us_ref / max(us_batch_per_round, 1e-9):.1f}x"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=64)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tasks", type=int, default=200)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for row in bench_route_step(args.streams, args.steps):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    for row in bench_realize(args.tasks):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")


if __name__ == "__main__":
    main()
