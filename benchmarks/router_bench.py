"""Router engine benchmark: steady-state ``route_step`` latency, the fused
scan drivers, the CCG sweep, and simulator realization throughput.

  PYTHONPATH=src python benchmarks/router_bench.py [--streams 64] [--steps 50]
  PYTHONPATH=src python benchmarks/router_bench.py --json   # + BENCH_router.json
  PYTHONPATH=src python benchmarks/router_bench.py --check BENCH_router.json

Prints ``name,us_per_call,derived`` CSV lines (the repo benchmark contract):

  router/route_step      — steady-state latency of one jit-compiled streaming
                           step (fused gate + warm-started CCG + C6 repair)
                           and the derived segments/sec
  router/route_scan_per_segment — amortized per-segment cost when a whole
                           multi-segment round runs under one lax.scan
  router/solve_ccg       — the unrolled masked CCG sweep alone
  router/solve_ccg_while — the legacy per-task while_loop CCG (the unrolled
                           solver's oracle), plus the unrolled speedup
  router/route_windowed  — the stateless windowed ``route`` on the same load
                           (re-scans the whole feature window each call)
  engine/serve_scan_per_round — whole-run driver (route + realize per round,
                           all rounds in one compiled scan)
  sim/realize_vectorized — jnp ``Simulator.realize`` path
  sim/realize_reference  — original per-task loop, plus max metric deviation
                           between the two on a fixed seed
  sim/realize_batch_per_round — amortized per-round cost when whole rounds
                           are realized in one vmapped batch
  policy/{name}          — every registered policy (a2_cloud_only, jcab,
                           rdap, sniper, r2evid) through the SAME compiled
                           ``ServeSession.run`` scan: µs per routed+realized
                           round at the default M, so baseline and R2E-VID
                           numbers are apples-to-apples compiled programs
  policy/{name}@{scenario} — the same compiled serve run through a named
                           adverse scenario (``repro.serving.scenarios``):
                           availability masks, bandwidth traces, and hedged
                           realization fused into the one scan, so the
                           scenario engine's compiled overhead is a gated
                           number, not a hope (all policies x edge_outage /
                           bw_collapse, r2evid x the rest of the suite)
  sweep/{stage}@M{m}     — ``--streams-sweep`` rows: per-stage latency (gate,
                           stage1, ccg, repair, realize, and the full
                           route_step) at each stream count M, with
                           us_per_segment derived so batch amortization —
                           and the LPT-packing realization wall — is
                           measured, not assumed
  sweep/route_step_sharded@M{m} / sweep/route_step_hier@M{m}
                         — ``--sharded-sweep`` rows: the whole compiled
                           sharded serve round on a FAKED 8-device host mesh
                           (subprocess — the device count locks at jax init),
                           gathered tail vs the hierarchical O(n_devices)
                           tail, so the claim that killing the per-round
                           O(M) all-gather does not cost latency is a
                           checked-in measured number (``vs_gathered`` in
                           the hier rows' derived field)

With ``--json`` the same rows are written to ``BENCH_router.json`` so every
PR records the perf trajectory (CI uploads it as an artifact), and a
one-line snapshot (commit, date, backend, headline router/ and sweep rows)
is appended to ``BENCH_history.jsonl`` — the append-only per-PR perf log
that survives baseline refreshes overwriting the JSON.  With
``--check PATH`` the run becomes a regression gate: any benchmark more than
``REGRESSION_FACTOR``x slower than the same-named row in the checked-in
baseline fails the process (loose threshold — shared runners are noisy and
CI runs tiny smoke sizes against the full-size baseline).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

# --check fails any benchmark this much slower than its baseline row
REGRESSION_FACTOR = 2.0


def _timeit(fn, iters: int, chunks: int = 3) -> float:
    """Best-of-``chunks`` mean latency in µs — the min over chunks is the
    standard noise-robust estimator on shared machines."""
    fn()  # warm-up / compile
    per_chunk = max(iters // chunks, 1)
    best = float("inf")
    for _ in range(chunks):
        t0 = time.perf_counter()
        for _ in range(per_chunk):
            fn()
        best = min(best, (time.perf_counter() - t0) / per_chunk)
    return best * 1e6  # us


def bench_route_step(streams: int, steps: int, window: int = 8,
                     scan_segments: int = 16):
    from repro.core.cost_model import SystemConfig
    from repro.core.features import feature_dim
    from repro.core.gating import GateConfig, gate_specs
    from repro.core.robust import (RobustProblem, solve_ccg, solve_ccg_fused,
                                   solve_ccg_while)
    from repro.core.router import RouterEngine, route
    from repro.models.params import init_params

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.uniform(0, 1, streams), jnp.float32)
    aq = jnp.asarray(rng.uniform(0.5, 0.75, streams), jnp.float32)
    dx = jnp.asarray(rng.normal(size=(streams, feature_dim())), jnp.float32)

    engine = RouterEngine(prob, gcfg, gparams, n_streams=streams)

    def step():
        sol = engine.step(dx, z, aq)
        jax.block_until_ready(sol["route"])

    us_step = _timeit(step, steps)
    seg_per_s = streams / (us_step / 1e6)

    dx_seq = jnp.asarray(
        rng.normal(size=(scan_segments, streams, feature_dim())), jnp.float32)

    def scan_round():
        sols = engine.step_many(dx_seq, z, aq)
        jax.block_until_ready(sols["route"])

    us_scan = _timeit(scan_round, max(steps // 4, 3)) / scan_segments
    scan_seg_per_s = streams / (us_scan / 1e6)

    def ccg_fused():
        sol = solve_ccg_fused(prob, z, aq)
        jax.block_until_ready(sol["route"])

    us_ccg_fused = _timeit(ccg_fused, steps)

    def ccg():
        sol = solve_ccg(prob, z, aq)
        jax.block_until_ready(sol["route"])

    us_ccg = _timeit(ccg, steps)

    def ccg_while():
        sol = solve_ccg_while(prob, z, aq)
        jax.block_until_ready(sol["route"])

    us_ccg_while = _timeit(ccg_while, steps)

    dx_win = jnp.asarray(rng.normal(size=(streams, window, feature_dim())), jnp.float32)

    def windowed():
        sol = route(prob, gcfg, gparams, dx_win, z, aq)
        jax.block_until_ready(sol["route"])

    us_win = _timeit(windowed, max(steps // 4, 3))
    return [
        ("router/route_step", us_step, f"segments_per_s={seg_per_s:.0f}"),
        ("router/route_scan_per_segment", us_scan,
         f"segments_per_s={scan_seg_per_s:.0f},scan_len={scan_segments}"),
        ("router/solve_ccg_fused", us_ccg_fused,
         f"tasks={streams},vs_unrolled={us_ccg / max(us_ccg_fused, 1e-9):.2f}x"),
        ("router/solve_ccg", us_ccg, f"tasks={streams}"),
        ("router/solve_ccg_while", us_ccg_while,
         f"tasks={streams},unrolled_speedup={us_ccg_while / max(us_ccg, 1e-9):.2f}x"),
        ("router/route_windowed", us_win, f"window={window}"),
    ]


def bench_policies(streams: int, rounds: int, iters: int = 5):
    """Every registered policy through the one compiled ``ServeSession.run``
    scan — the apples-to-apples serving comparison the paper's claims rest
    on (baselines get batching + donation + the fused realization exactly
    like R2E-VID).  µs per routed+realized round."""
    from repro.core.cost_model import SystemConfig
    from repro.core.features import feature_dim
    from repro.core.gating import GateConfig, gate_specs
    from repro.models.params import init_params
    from repro.serving.policy import POLICIES, make_policy
    from repro.serving.session import ServeSession
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    sim = Simulator(sys_, SimConfig(n_tasks=streams, seed=11, bw_fluctuation=0.2))
    stream = sim.sample_stream(n_rounds=rounds, feature_seed=2)
    rows = []
    for name in sorted(POLICIES):
        if name == "r2evid":
            gcfg = GateConfig(d_feature=feature_dim())
            gp = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
            policy = make_policy(name, sys_, gate_cfg=gcfg, gate_params=gp)
        else:
            policy = make_policy(name, sys_)
        session = ServeSession(policy, n_streams=streams, sim=sim.sim)

        def run():
            mets = session.run(stream)
            jax.block_until_ready(mets["cost"])

        us = _timeit(run, iters) / rounds
        rows.append((f"policy/{name}", us,
                     f"rounds={rounds},streams={streams},us_per_segment="
                     f"{us / streams:.3f}"))
    return rows


def bench_scenarios(streams: int, rounds: int, iters: int = 5,
                    scenarios=("edge_outage", "bw_collapse", "churn")):
    """Degraded serving: every registered policy through the SAME compiled
    ``ServeSession.run`` scan under the named adverse scenarios, plus
    r2evid through the rest of the suite — ``policy/{name}@{scenario}``
    rows with the same per-round-µs contract as ``policy/{name}``, so
    ``--check`` gates the scenario engine's compiled overhead (availability
    masks, bandwidth traces, hedged realization) exactly like the benign
    path."""
    from repro.core.cost_model import SystemConfig
    from repro.serving.policy import POLICIES, make_policy
    from repro.serving.scenarios import (SUITE, apply_scenario,
                                         compile_scenario)
    from repro.serving.session import ServeSession
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    simc = SimConfig(n_tasks=streams, n_rounds=rounds, seed=11,
                     bw_fluctuation=0.2)
    stream = Simulator(sys_, simc).sample_stream(rounds)
    cells = [(p, s) for s in scenarios for p in sorted(POLICIES)]
    cells += [("r2evid", s) for s in SUITE if s not in scenarios]
    rows = []
    for name, scen in cells:
        trace = compile_scenario(scen, sys_, simc, rounds)
        degraded = apply_scenario(stream, trace)
        session = ServeSession(make_policy(name, sys_), streams, sim=simc,
                               hedge=trace.hedge, admission=trace.admission)

        def run(session=session, degraded=degraded):
            mets = session.run(degraded)
            jax.block_until_ready(mets["cost"])

        us = _timeit(run, iters) / rounds
        rows.append((f"policy/{name}@{scen}", us,
                     f"rounds={rounds},streams={streams}"))
    return rows


def bench_streams_sweep(sweep, steps: int):
    """Stream-count scaling of the table-free hot path: per-stage µs at each
    M plus the full ``route_step``.  The per-segment µs in ``derived`` is the
    checked-in evidence that large-M batches amortize (sub-linear scaling):
    ``per_seg_vs_M{m0}`` is the ratio of this row's µs/segment to the
    smallest-M row's — < 1.0 means batching wins.  The ``realize`` stage
    times ``realize_rounds`` (fair-share transmission + LPT queueing +
    pointwise accuracy) on one M-task round — the ROADMAP's suspected next
    scaling wall is its sequential O(M) packing scan, so its per-segment
    µs is the number to watch."""
    from repro.core.cost_model import SystemConfig
    from repro.core.features import feature_dim
    from repro.core.gating import GateConfig, gate_specs, gate_step_batch, init_batch_state
    from repro.core.robust import RobustProblem, solve_ccg_fused
    from repro.core.router import (
        RouterEngine,
        enforce_bandwidth,
        stage1_configure,
    )
    from repro.models.params import init_params
    from repro.serving.simulator import realize_rounds

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))

    gate_j = jax.jit(lambda st, dx: gate_step_batch(gcfg, gparams, st, dx))
    stage1_j = jax.jit(
        lambda taus, z, aq, pr, pt: stage1_configure(sys_, taus, z, aq, pr, pt))
    repair_j = jax.jit(
        lambda sol, z, aq: enforce_bandwidth(prob.lat, sol, z, aq))

    rows = []
    base_per_seg = {}
    m0 = sweep[0]
    for m in sweep:
        rng = np.random.default_rng(m)
        z = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
        aq = jnp.asarray(rng.uniform(0.5, 0.75, m), jnp.float32)
        dx = jnp.asarray(rng.normal(size=(m, feature_dim())), jnp.float32)
        taus = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
        prev_r = -jnp.ones((m,), jnp.int32)
        prev_t = jnp.zeros((m,), jnp.float32)
        # floor of 10: the cheap stages (stage1/realize, ~100-300 µs) are
        # dispatch-noise-dominated; the CI smoke's tiny --steps would give
        # best-of-1-call chunks and flake the --check gate
        iters = max(steps // 3, 10)

        gate_st = init_batch_state(gcfg, m)

        def bench_gate():
            st, (tau, _) = gate_j(gate_st, dx)
            jax.block_until_ready(tau)

        def bench_stage1():
            route, r = stage1_j(taus, z, aq, prev_r, prev_t)
            jax.block_until_ready(route)

        def bench_ccg():
            sol = solve_ccg_fused(prob, z, aq)
            jax.block_until_ready(sol["route"])

        sol0 = solve_ccg_fused(prob, z, aq)
        sol_fixed = {k: sol0[k] for k in ("route", "r", "p", "v")}

        def bench_repair():
            fixed, _ = repair_j(sol_fixed, z, aq)
            jax.block_until_ready(fixed["r"])

        bwm = jnp.asarray(rng.uniform(0.8, 1.0, 2), jnp.float32)
        u_real = jnp.asarray(rng.uniform(0, 0.3, sys_.num_versions), jnp.float32)

        def bench_realize_round():
            met = realize_rounds(
                sys_, z, bwm, u_real, sol_fixed["route"], sol_fixed["r"],
                sol_fixed["p"], sol_fixed["v"], n_edge=4, n_cloud=1)
            jax.block_until_ready(met["cost"])

        engine = RouterEngine(prob, gcfg, gparams, n_streams=m)

        def bench_step():
            sol = engine.step(dx, z, aq)
            jax.block_until_ready(sol["route"])

        stages = [("gate", bench_gate), ("stage1", bench_stage1),
                  ("ccg", bench_ccg), ("repair", bench_repair),
                  ("realize", bench_realize_round),
                  ("route_step", bench_step)]
        for stage, fn in stages:
            us = _timeit(fn, iters)
            per_seg = us / m
            derived = f"streams={m},us_per_segment={per_seg:.3f}"
            if stage == "route_step":
                derived += f",segments_per_s={m / (us / 1e6):.0f}"
            if m != m0 and stage in base_per_seg:
                derived += (f",per_seg_vs_M{m0}="
                            f"{per_seg / base_per_seg[stage]:.3f}")
            else:
                base_per_seg[stage] = per_seg
            rows.append((f"sweep/{stage}@M{m}", us, derived))
    return rows


def bench_sharded_child(sweep, rounds: int, iters: int):
    """Runs INSIDE the faked-device subprocess: one compiled sharded serve
    scan per (M, tail-mode) cell, gathered vs hierarchical, µs per round.
    The pools are sized 2/1 servers per device so the hierarchical static
    partition divides evenly at any device count."""
    from repro.core.cost_model import SystemConfig
    from repro.serving.policy import make_policy
    from repro.serving.session import ServeSession
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    pol = make_policy("r2evid", sys_)
    rows = []
    for m in sweep:
        simc = SimConfig(n_tasks=m, n_rounds=rounds, seed=m,
                         bw_fluctuation=0.2)
        stream = Simulator(sys_, simc).sample_stream(rounds)
        kw = dict(sim=simc, n_edge=2 * n_dev, n_cloud=n_dev)
        sess_g = ServeSession(pol, m, **kw)

        def run_g():
            mets = sess_g.run_sharded(mesh, stream)
            jax.block_until_ready(mets["cost"])

        us_g = _timeit(run_g, iters) / rounds
        sess_h = ServeSession(pol, m, hierarchical=True, **kw)

        def run_h():
            mets = sess_h.run_sharded(mesh, stream)
            jax.block_until_ready(mets["cost"])

        us_h = _timeit(run_h, iters) / rounds
        rows.append((f"sweep/route_step_sharded@M{m}", us_g,
                     f"streams={m},devices={n_dev},"
                     f"us_per_segment={us_g / m:.3f}"))
        rows.append((f"sweep/route_step_hier@M{m}", us_h,
                     f"streams={m},devices={n_dev},"
                     f"us_per_segment={us_h / m:.3f},"
                     f"vs_gathered={us_h / max(us_g, 1e-9):.3f}x"))
    return rows


def bench_sharded(sweep_csv: str, rounds: int, steps: int, n_dev: int = 8):
    """Spawn the faked-``n_dev``-device child (the device count locks at
    first jax init, so the parent process cannot fake it itself) and parse
    its CSV rows back into the parent's row list."""
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        JAX_PLATFORMS="cpu",
    )
    cmd = [sys.executable, __file__, "--_sharded-child",
           "--sharded-sweep", sweep_csv, "--scan-rounds", str(rounds),
           "--steps", str(steps)]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=3600)
    if out.returncode != 0:
        raise RuntimeError("sharded bench child failed:\n"
                           + out.stderr[-3000:])
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("sweep/route_step_"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    if not rows:
        raise RuntimeError("sharded bench child produced no rows:\n"
                           + out.stdout[-2000:])
    return rows


def bench_serve_scan(streams: int, rounds: int, iters: int = 5):
    from repro.core.cost_model import SystemConfig
    from repro.core.features import feature_dim
    from repro.core.gating import GateConfig, gate_specs
    from repro.core.robust import RobustProblem
    from repro.core.router import init_router_state
    from repro.models.params import init_params
    from repro.serving.scan import serve_scan
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    prob = RobustProblem.build(sys_)
    gcfg = GateConfig(d_feature=feature_dim())
    gparams = init_params(gate_specs(gcfg), jax.random.PRNGKey(0))
    sim = Simulator(sys_, SimConfig(n_tasks=streams, seed=5, bw_fluctuation=0.2))
    rnds = [sim.sample_round() for _ in range(rounds)]
    rng = np.random.default_rng(1)
    dx_seq = jnp.asarray(rng.normal(size=(rounds, streams, feature_dim())), jnp.float32)
    z = jnp.asarray(np.stack([r["z"] for r in rnds]), jnp.float32)
    aq = jnp.asarray(np.stack([r["aq"] for r in rnds]), jnp.float32)
    bwm = jnp.asarray(np.stack([r["bw_mult"] for r in rnds]), jnp.float32)
    u = jnp.asarray(np.stack([r["u"] for r in rnds]), jnp.float32)
    # the compiled scan donates its carry, so the state must be threaded
    # (exactly how a real serving loop uses it) rather than reused
    carry = {"state": init_router_state(gcfg, streams)}

    def run():
        carry["state"], mets = serve_scan(
            prob, gcfg, gparams, carry["state"], dx_seq, z, aq, bwm, u,
            n_edge=sim.sim.n_edge_servers, n_cloud=sim.sim.n_cloud_servers)
        jax.block_until_ready(mets["cost"])

    us = _timeit(run, iters) / rounds
    return [("engine/serve_scan_per_round", us,
             f"rounds={rounds},streams={streams}")]


def bench_realize(n_tasks: int, iters: int = 20):
    from repro.core.cost_model import SystemConfig
    from repro.serving.baselines import make_method
    from repro.serving.simulator import SimConfig, Simulator

    sys_ = SystemConfig()
    sim = Simulator(sys_, SimConfig(n_tasks=n_tasks, seed=3, bw_fluctuation=0.2))
    rnd = sim.sample_round()
    cfg = make_method("JCAB", sys_)(rnd, {})

    us_vec = _timeit(lambda: sim.realize(rnd, cfg), iters)
    us_ref = _timeit(lambda: sim.realize_reference(rnd, cfg), iters)

    n_batch = 16
    rnds = [rnd] * n_batch
    cfgs = [cfg] * n_batch
    us_batch = _timeit(lambda: sim.realize_batch(rnds, cfgs), max(iters // 4, 3))
    us_batch_per_round = us_batch / n_batch

    # parity on a fixed seed: identical observation noise for both paths
    noise = np.zeros(n_tasks)
    met_v = sim._realize_deterministic(rnd, cfg)
    met_r = sim.realize_reference(rnd, cfg, noise=noise)
    dev = max(
        float(np.abs(met_v[k] - met_r[k]).max())
        for k in ("delay", "energy", "cost", "accuracy")
    )
    return [
        ("sim/realize_vectorized", us_vec, f"n_tasks={n_tasks}"),
        ("sim/realize_reference", us_ref,
         f"speedup={us_ref / max(us_vec, 1e-9):.1f}x,max_dev={dev:.2e}"),
        ("sim/realize_batch_per_round", us_batch_per_round,
         f"rounds={n_batch},speedup_vs_loop={us_ref / max(us_batch_per_round, 1e-9):.1f}x"),
    ]


def check_regressions(rows, baseline_path: str) -> int:
    """Compare rows against a baseline JSON; return the number of rows more
    than REGRESSION_FACTOR x slower (rows without a baseline entry pass)."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    base_us = {b["name"]: b["us_per_call"] for b in base["benchmarks"]}
    bad = 0
    for name, us, _ in rows:
        ref = base_us.get(name)
        if ref is None:
            print(f"check: {name} has no baseline row — skipped")
            continue
        ratio = us / max(ref, 1e-9)
        verdict = "REGRESSION" if ratio > REGRESSION_FACTOR else "ok"
        print(f"check: {name} {us:.1f}us vs baseline {ref:.1f}us "
              f"({ratio:.2f}x) {verdict}")
        bad += ratio > REGRESSION_FACTOR
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=64)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tasks", type=int, default=200)
    ap.add_argument("--scan-rounds", type=int, default=16)
    ap.add_argument("--streams-sweep", default="64,256,512,1024,4096",
                    help="comma-separated stream counts for the per-stage "
                         "large-M scaling rows (empty string disables; 512 "
                         "stays in the default so baseline refreshes keep "
                         "the M=512 rows CI checks against)")
    ap.add_argument("--sharded-sweep", default="256,1024,4096",
                    help="comma-separated stream counts for the sharded "
                         "serve rows on a faked 8-device host mesh (empty "
                         "string disables; runs in a subprocess)")
    ap.add_argument("--_sharded-child", dest="_sharded_child",
                    action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_router.json next to the repo root")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail if any benchmark is >%.0fx slower than the "
                         "same-named row in this baseline JSON" % REGRESSION_FACTOR)
    args = ap.parse_args()

    if args._sharded_child:
        sweep = [int(s) for s in args.sharded_sweep.split(",")]
        for name, us, derived in bench_sharded_child(
                sweep, args.scan_rounds, max(args.steps // 6, 3)):
            print(f"{name},{us:.3f},{derived}")
        return

    rows = []
    rows += bench_route_step(args.streams, args.steps)
    rows += bench_serve_scan(args.streams, args.scan_rounds)
    rows += bench_policies(args.streams, args.scan_rounds)
    rows += bench_scenarios(args.streams, args.scan_rounds)
    rows += bench_realize(args.tasks)
    if args.streams_sweep:
        sweep = [int(s) for s in args.streams_sweep.split(",")]
        rows += bench_streams_sweep(sweep, args.steps)
    if args.sharded_sweep:
        rows += bench_sharded(args.sharded_sweep, args.scan_rounds,
                              args.steps)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    n_bad = check_regressions(rows, args.check) if args.check else 0

    if args.json:
        out = {
            "config": {"streams": args.streams, "steps": args.steps,
                       "tasks": args.tasks, "scan_rounds": args.scan_rounds,
                       "streams_sweep": args.streams_sweep,
                       "backend": jax.default_backend()},
            "benchmarks": [
                {"name": name, "us_per_call": round(us, 2), "derived": derived,
                 "calls_per_s": round(1e6 / max(us, 1e-9), 1)}
                for name, us, derived in rows
            ],
        }
        root = pathlib.Path(__file__).resolve().parent.parent
        path = root / "BENCH_router.json"
        path.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {path}")

        # append-only per-PR trajectory: the baseline JSON is overwritten on
        # every refresh, so the history line is what lets a later PR see the
        # headline rows' evolution without archaeology through git
        headline = {
            name: round(us, 2) for name, us, _ in rows
            if name.startswith(("router/", "sweep/ccg@", "sweep/route_step"))
        }
        try:
            commit = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"], cwd=root,
                capture_output=True, text=True, check=True).stdout.strip()
        except (OSError, subprocess.CalledProcessError):
            commit = "unknown"
        snap = {"commit": commit,
                "date": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "backend": jax.default_backend(),
                "config": out["config"], "headline": headline}
        hist = root / "BENCH_history.jsonl"
        with hist.open("a") as f:
            f.write(json.dumps(snap) + "\n")
        print(f"appended snapshot to {hist}")

    if n_bad:
        sys.exit(f"{n_bad} benchmark(s) regressed >{REGRESSION_FACTOR}x")


if __name__ == "__main__":
    main()
