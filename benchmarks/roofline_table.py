"""Print the roofline table from collected dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os


def load_cells(out_dir="results/dryrun", mesh="single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append((r.get("arch"), r.get("shape"), "FAILED", 0, 0, 0, 0))
            continue
        t = r["terms"]
        rows.append((
            r["arch"], r["shape"], t["dominant"],
            t["compute_s"], t["memory_s"], t["collective_s"],
            r["useful_flops_ratio"],
        ))
    return rows


def print_table(out_dir="results/dryrun"):
    rows = load_cells(out_dir)
    if not rows:
        print("# no dry-run artifacts found; run: PYTHONPATH=src python -m repro.launch.dryrun")
        return rows
    print(f"# {'arch':22s} {'shape':12s} {'dominant':10s} {'compute_ms':>10s} "
          f"{'memory_ms':>10s} {'coll_ms':>10s} {'useful':>7s}")
    for a, s, d, c, m, co, u in rows:
        print(f"# {a:22s} {s:12s} {d:10s} {c*1e3:10.1f} {m*1e3:10.1f} {co*1e3:10.1f} {u:7.2f}")
    return rows
