"""Quickstart: R2E-VID two-stage robust routing in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GateConfig, RobustProblem, SystemConfig, feature_dim,
                        gate_specs, route, segment_features)
from repro.data.video import VideoConfig, generate_stream, make_task_batch
from repro.models.params import init_params

# 1. synthesize a batch of video streams (moving-blob scenes)
vcfg = VideoConfig()
streams = [generate_stream(vcfg, n_segments=8, rng=np.random.default_rng(i))
           for i in range(6)]

# 2. motion features Δx_t = φ(I_t, I_{t-1})  (paper §3.2)
dx = jnp.stack([segment_features(jnp.asarray(frames), vcfg.frames_per_segment)
                for frames, _ in streams])            # (streams, segments, d)
difficulty = jnp.asarray([m.mean() for _, m in streams])

# 3. temporal gate + two-stage robust routing (paper Alg. 1 + Alg. 2)
sys_cfg = SystemConfig()
prob = RobustProblem.build(sys_cfg)
gate_cfg = GateConfig(d_feature=feature_dim())
gate_params = init_params(gate_specs(gate_cfg), jax.random.PRNGKey(0))
acc_req = jnp.asarray(make_task_batch(len(streams), "stable"))

sol = route(prob, gate_cfg, gate_params, dx, difficulty, acc_req)

res = [sys_cfg.resolutions[i] for i in np.asarray(sol["r"])]
fps = [sys_cfg.fps_options[i] for i in np.asarray(sol["p"])]
for i in range(len(streams)):
    tier = "cloud" if int(sol["route"][i]) else "edge"
    print(f"stream {i}: τ={float(sol['tau'][i]):.2f} z={float(difficulty[i]):.2f} "
          f"A^q={float(acc_req[i]):.2f} -> {tier:5s} {res[i]}p@{fps[i]}fps model=v{int(sol['v'][i])+1}")
print(f"robust objective (O_up): {np.asarray(sol['o_up']).round(3).tolist()}")
