"""Degraded serving demo: one policy through a compiled fault scenario.

Compiles the ``edge_outage`` scenario (the edge pool dies at R//3 and
recovers staggered) into per-round arrays, serves the whole degraded run
inside ONE ``ServeSession.run`` scan, and prints the Table-2-generalized
robustness scalars — then does the same under ``bw_collapse`` and the
hedged ``straggler_tail`` so the three fault families (availability,
bandwidth, latency tail) are all exercised.

  PYTHONPATH=src python examples/serve_degraded.py [--policy r2evid]
"""
import argparse

import numpy as np

from repro.core.cost_model import SystemConfig
from repro.serving.scenarios import compile_scenario, run_scenario
from repro.serving.simulator import SimConfig

STREAMS, ROUNDS = 32, 18

ap = argparse.ArgumentParser()
ap.add_argument("--policy", default="r2evid")
args = ap.parse_args()

sys_ = SystemConfig()
simc = SimConfig(n_tasks=STREAMS, n_rounds=ROUNDS, seed=11,
                 bw_fluctuation=0.2)

for name in ("none", "edge_outage", "bw_collapse", "straggler_tail",
             "churn", "flash_churn", "outage_collapse"):
    trace = compile_scenario(name, sys_, simc)
    scalars, mets = run_scenario(args.policy, trace, streams=STREAMS,
                                 rounds=ROUNDS, return_mets=True)
    print(f"\n== {args.policy} @ {name} ==")
    for k in ("cost", "delay", "accuracy", "sla_violation_rate", "sla_cost",
              "cloud_frac", "recovery_rounds"):
        print(f"  {k:20s} {scalars[k]:.4f}")
    if "alive" in mets:     # slot-pool churn: occupancy + backpressure
        print(f"  {'mean_alive':20s} {scalars['mean_alive']:.2f} / {STREAMS}"
              f"   max_queue={scalars['max_queue_depth']:.0f}"
              f" dropped={scalars['dropped']:.0f}")
    if trace.onset is not None:
        cost_r = np.asarray(mets["cost"]).mean(axis=1)
        spark = " ".join(f"{c:.1f}" for c in cost_r)
        print(f"  per-round cost (onset at r{trace.onset}): {spark}")
    if name == "edge_outage":
        route = np.asarray(mets["route"])
        masked = np.asarray(trace.tier_ok)[:, 0] == 0
        assert (route[masked] == 1).all()
        print(f"  {int(masked.sum())} rounds router-masked; every segment "
              f"in them realized on the cloud tier")
