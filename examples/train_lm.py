"""End-to-end training driver: train a ~20M-param qwen-family model for a few
hundred steps on CPU (the full configs run the same path on the TPU mesh).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # widen the smoke config to ~20M params (still CPU-friendly)
    cfg = dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=768, vocab_size=8192, attn_chunk=64, loss_chunk=64,
    )
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=100, ckpt_dir="results/ckpt_example",
        log_every=20,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    data = iter(TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0))
    tr = Trainer(cfg, tcfg)
    _, hist = tr.run(data)
    for h in hist:
        print(f"step {h['step']:5d} loss {h['loss']:.4f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not improve"
    print("training improved loss:", round(hist[0]["loss"], 3), "->", round(hist[-1]["loss"], 3))


if __name__ == "__main__":
    main()
