"""Fault tolerance demo: node failure mid-training -> checkpoint restore on a
re-built (elastic) mesh -> training continues.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import shutil

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.runtime.cluster import ClusterSim, FailureInjector, elastic_remesh
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import NodeFailure, TrainConfig, Trainer

CKPT = "results/ckpt_failover"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_smoke_config("minitron-8b")
tcfg = TrainConfig(steps=60, ckpt_every=20, ckpt_dir=CKPT, log_every=20,
                   opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=60))
data = iter(TokenPipeline(cfg.vocab_size, 64, 4, seed=0))

cluster = ClusterSim(n_nodes=4)
injector = FailureInjector(schedule={35: "node 2 heartbeat timeout"})

print("phase 1: training with failure scheduled at step 35")
tr = Trainer(cfg, tcfg, failure_injector=injector)
try:
    tr.run(data)
except NodeFailure as e:
    print(f"  !! {e}")
    cluster.kill(2)

print(f"phase 2: elastic re-mesh with {cluster.alive}/{cluster.n_nodes} nodes")
# trainer recovery keeps the model (TP) axis as large as the survivors
# allow; serving recovery would use prefer="data" instead (streams shard
# along data only — see examples/serve_degraded.py)
mesh = elastic_remesh(cluster.alive, prefer="model")
print(f"  new mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

print("phase 3: restore latest checkpoint and resume")
tr2 = Trainer(cfg, tcfg)  # fresh process semantics
resume_step = tr2.ckpt.latest_step()
state, hist = tr2.run(data)
for h in hist:
    print(f"  step {h['step']:4d} loss {h['loss']:.4f}")
print(f"recovered: resumed from step {resume_step} -> finished at {tr2.step}")
