"""Routed serving: one ``ServeSession`` owning the gate-mode r2evid policy
(fused batched gate recurrence + warm-started robust two-stage selection per
segment) and the live edge/cloud model pools its decisions dispatch onto.
Each round's segments run under one compiled ``lax.scan``
(``session.route_many``); swap ``--policy`` for any registered baseline.

  PYTHONPATH=src python examples/serve_routed.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--rounds", "3", "--streams", "8",
                "--segments-per-round", "4"]
    main()
