"""Routed serving: the stateful streaming router engine (fused batched gate
recurrence + warm-started robust two-stage selection per segment) dispatching
batched requests onto live edge/cloud model pools.  Each round's segments run
under one compiled ``lax.scan`` (``RouterEngine.step_many``).

  PYTHONPATH=src python examples/serve_routed.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--rounds", "3", "--streams", "8",
                "--segments-per-round", "4"]
    main()
