"""Routed serving: the stateful streaming router engine (gate recurrence +
robust two-stage selection per segment) dispatching batched requests onto
live edge/cloud model pools.

  PYTHONPATH=src python examples/serve_routed.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--rounds", "3", "--streams", "8",
                "--segments-per-round", "4"]
    main()
